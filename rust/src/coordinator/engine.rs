//! The unified [`AdapterEngine`]: one `&self + Sync` execution facade
//! over pluggable [`ExecutionStrategy`] implementations.
//!
//! Before this module the backend API had sprawled into two
//! near-duplicate traits (`GenBackend` with `&mut self`, `SharedBackend`
//! with `&self + Sync`) and three backend structs (`PjrtBackend`,
//! `HostMergeBackend`, `HostPoolBackend`). Every execution path is now
//! one object-safe trait — [`ExecutionStrategy`], `&self + Sync` by
//! contract, so the same instance drives the single-threaded
//! [`Server::pump`](super::server::Server::pump), the concurrent
//! [`Server::pump_pool`](super::server::Server::pump_pool) worker stage,
//! and the threaded [`Server::serve`](super::server::Server::serve) loop
//! without blanket-impl adapters.
//!
//! # Strategies
//!
//! * [`MergedCacheStrategy`] (`"merged"`) — merge-on-demand through the
//!   [`MergeEngine`] LRU cache: one full model copy per cached adapter,
//!   single-flight deduplication, concurrency-friendly. The hot-adapter
//!   workhorse (a cache hit is a lock-and-clone).
//! * [`InvolutionSwapStrategy`] (`"swap"`) — a single in-place
//!   [`SwapSlot`] rewritten on every adapter change
//!   ([`SwapMode::Rebase`] bit-exact, [`SwapMode::Involution`] through
//!   the paper's H·H = I inversion): one model copy **total**. The slot
//!   is one mutable buffer, so batches serialize on its lock.
//! * [`OnTheFlyStrategy`] (`"onthefly"`) — **zero** merged buffers: the
//!   transform is applied directly to activations per work item
//!   (`y = T(W)·x`; for ETHER the O(d)-per-column reflection
//!   `H·y = y − 2û(ûᵀy)`) through
//!   `TransformOp::apply_activations_into`. Serving an adapter costs
//!   O(1) extra memory however many adapters rotate through — the cold
//!   long-tail strategy.
//! * [`PjrtMergedStrategy`] (`"pjrt-merged"`) — merge via the HLO
//!   `merge` artifact, greedy decode through the compiled model, with
//!   the same merged-weight LRU semantics behind a mutex.
//!
//! # Policy
//!
//! [`ExecutionPolicy`] picks the strategy per adapter:
//! [`ExecutionPolicy::Static`] routes everything through one strategy;
//! [`ExecutionPolicy::TrafficAware`] watches the per-adapter request
//! counters the scheduler feeds through
//! [`ExecutionStrategy::record_traffic`] and **promotes** an adapter to
//! the merged cache once its cumulative request count reaches the
//! threshold — hot adapters get merged buffers, the cold tail is served
//! merge-free. Promotions are sticky and counted
//! ([`StrategyCounters::policy_promotions`]).
//!
//! # Composition stacks
//!
//! A request may name an ordered adapter stack (`"a+b+c"`): every host
//! strategy serves it through [`ExecutionStrategy::generate_stack`] —
//! merged folds `T_c(T_b(T_a(W)))` into one cached buffer keyed by the
//! full stack id, swap rotates its single slot between whole stacks
//! (reverse-order unmerge, whole-chain audit), and on-the-fly chains
//! the ops' affine composition factors around one base GEMM with zero
//! merged buffers. Policy and traffic are keyed by the full stack id
//! (`"a+b"` earns promotion on its own traffic), and length-1 stacks
//! take the singleton path bit-for-bit.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

use super::registry::{join_stack_id, AdapterEntry, MergeEngine, MergedCache, SwapMode, SwapSlot};
use crate::peft::precision::MergedBuf;
use crate::runtime::engine::PjrtEngine;
use crate::runtime::HostTensor;
use crate::util::sync::lock_clean;

/// Cheap fingerprint proving which weights (or adapted activations)
/// served a batch: a strided bit-fold over the whole vector, so it stays
/// adapter-distinct regardless of where the adapted values sit.
pub fn weights_fingerprint(data: &[f32]) -> i32 {
    let stride = data.len() / 64 + 1;
    data.iter()
        .step_by(stride)
        .fold(0u32, |acc, x| acc.rotate_left(5) ^ x.to_bits()) as i32
}

/// [`weights_fingerprint`] of column `c` of a row-major `…×m` activation
/// buffer (the batched GEMM output for request `c`). The gathered column
/// is bit-identical to an `m = 1` activation run over that request's
/// probe column, so batched and per-vector serving produce the **same**
/// per-request tags — the equivalence `rust/tests/kernel_props.rs` pins.
pub fn column_fingerprint(y: &[f32], m: usize, c: usize) -> i32 {
    debug_assert!(c < m && y.len() % m == 0);
    let col: Vec<f32> = y.iter().skip(c).step_by(m).copied().collect();
    weights_fingerprint(&col)
}

/// Echo decode shared by the host strategies: each prompt comes back
/// with the strategy's weight/activation fingerprint appended, so tests
/// and benches can observe which weights served which request.
fn echo_tagged(prompts: &[Vec<i32>], tag: i32) -> Vec<Vec<i32>> {
    prompts
        .iter()
        .map(|p| {
            let mut o = p.clone();
            o.push(tag);
            o
        })
        .collect()
}

/// Per-strategy serving counters, surfaced into
/// [`ServerStats`](super::server::ServerStats) after every pump.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StrategyCounters {
    /// Requests served through the merged-weight cache strategy.
    pub served_merged: u64,
    /// Requests served merge-free through the on-the-fly strategy.
    pub served_onthefly: u64,
    /// Requests served through the in-place swap strategy.
    pub served_swap: u64,
    /// Cold→hot promotions performed by a traffic-aware policy.
    pub policy_promotions: u64,
}

/// Object-safe execution strategy: how an adapter's weights meet a
/// released batch. `&self + Sync + Send` by contract, so one instance
/// serves any number of concurrent pool workers (and moves into the
/// threaded serve loop).
pub trait ExecutionStrategy: Sync + Send {
    /// Short kind label (`"merged"` / `"swap"` / `"onthefly"` / ...).
    fn name(&self) -> &'static str;

    /// Execute one batch for `adapter`: one output row per prompt.
    fn generate(
        &self,
        adapter: &AdapterEntry,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>>;

    /// Execute one batch for an ordered adapter *stack* (members applied
    /// left to right: `[a, b]` serves `T_b(T_a(W))`). Default: a
    /// length-1 stack delegates to [`ExecutionStrategy::generate`] —
    /// existing strategies (and mocks) keep working unchanged — and
    /// longer stacks are rejected; every composition-capable strategy
    /// overrides this.
    fn generate_stack(
        &self,
        stack: &[AdapterEntry],
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        match stack {
            [single] => self.generate(single, prompts, max_new),
            [] => Err(anyhow!("adapter stack must be non-empty")),
            _ => Err(anyhow!(
                "strategy {:?} cannot serve composed adapter stacks",
                self.name()
            )),
        }
    }

    /// Cumulative (hits, misses) of any merged-weight cache behind this
    /// strategy — mirrored into `ServerStats` after each pump.
    fn merge_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Cumulative (in-place swaps, max audited involution residual).
    fn swap_stats(&self) -> (u64, f64) {
        (0, 0.0)
    }

    /// Per-strategy served counters (policy facades report real values;
    /// leaf strategies report zeros).
    fn strategy_counters(&self) -> StrategyCounters {
        StrategyCounters::default()
    }

    /// Scheduler feed: the cumulative released-request count for
    /// `adapter`. Policy-aware facades use it for promotion decisions;
    /// leaf strategies ignore it.
    fn record_traffic(&self, adapter: &str, requests: u64) {
        let _ = (adapter, requests);
    }

    /// Bytes of merged weights this strategy keeps resident.
    fn resident_weight_bytes(&self) -> usize {
        0
    }

    /// Real merge executions performed so far (cache misses that ran the
    /// merge kernel, swap-slot fills, …) — distinct from
    /// [`ExecutionStrategy::merge_stats`], which counts cache probes.
    fn merge_executions(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// Leaf strategies.
// ---------------------------------------------------------------------------

/// Merged-weight LRU strategy over the blocked parallel [`MergeEngine`]
/// (single-flight per adapter, bounded merge permits): any number of
/// pool workers serve batches at once. Decode is the fingerprint-tagged
/// echo (real model decode lives in [`PjrtMergedStrategy`]).
pub struct MergedCacheStrategy {
    merger: Arc<MergeEngine>,
}

impl MergedCacheStrategy {
    pub fn new(merger: Arc<MergeEngine>) -> MergedCacheStrategy {
        MergedCacheStrategy { merger }
    }
}

impl ExecutionStrategy for MergedCacheStrategy {
    fn name(&self) -> &'static str {
        "merged"
    }

    fn generate(
        &self,
        adapter: &AdapterEntry,
        prompts: &[Vec<i32>],
        _max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let tag = weights_fingerprint(&self.merger.merged(adapter)?);
        Ok(echo_tagged(prompts, tag))
    }

    /// Composed-merged: the whole stack folds into one cached buffer
    /// keyed by the stack id ([`MergeEngine::merged_stack`]).
    fn generate_stack(
        &self,
        stack: &[AdapterEntry],
        prompts: &[Vec<i32>],
        _max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let tag = weights_fingerprint(&self.merger.merged_stack(stack)?);
        Ok(echo_tagged(prompts, tag))
    }

    fn merge_stats(&self) -> (u64, u64) {
        self.merger.cache_stats()
    }

    fn resident_weight_bytes(&self) -> usize {
        self.merger.cache_resident_bytes()
    }

    fn merge_executions(&self) -> u64 {
        self.merger.merges.load(Ordering::SeqCst)
    }
}

/// In-place swap strategy: ONE merged buffer total, rewritten on every
/// adapter change through [`MergeEngine::swap_into`]. The slot is a
/// single mutable buffer, so concurrent batches serialize on its lock —
/// the memory-for-concurrency trade this strategy exists for.
pub struct InvolutionSwapStrategy {
    merger: Arc<MergeEngine>,
    slot: Mutex<SwapSlot>,
    mode: SwapMode,
}

impl InvolutionSwapStrategy {
    pub fn new(merger: Arc<MergeEngine>, mode: SwapMode) -> InvolutionSwapStrategy {
        let slot = merger.new_swap_slot();
        InvolutionSwapStrategy { merger, slot: Mutex::new(slot), mode }
    }
}

impl ExecutionStrategy for InvolutionSwapStrategy {
    fn name(&self) -> &'static str {
        "swap"
    }

    fn generate(
        &self,
        adapter: &AdapterEntry,
        prompts: &[Vec<i32>],
        _max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let mut slot = lock_clean(&self.slot);
        self.merger.swap_into(&mut slot, adapter, self.mode)?;
        let tag = weights_fingerprint(slot.weights());
        Ok(echo_tagged(prompts, tag))
    }

    /// Composed swap: the single slot rotates between whole stacks
    /// ([`MergeEngine::swap_into_stack`] — the resident composition is
    /// unmerged in strict reverse order, audit covering the full chain).
    fn generate_stack(
        &self,
        stack: &[AdapterEntry],
        prompts: &[Vec<i32>],
        _max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let mut slot = lock_clean(&self.slot);
        self.merger.swap_into_stack(&mut slot, stack, self.mode)?;
        let tag = weights_fingerprint(slot.weights());
        Ok(echo_tagged(prompts, tag))
    }

    /// Swap semantics: a "hit" is an already-resident adapter, a "miss"
    /// is any rewrite (the first fill counts in `merges`).
    fn merge_stats(&self) -> (u64, u64) {
        let (swaps, hits, _) = self.merger.swap_stats();
        (hits, swaps + self.merger.merges.load(Ordering::SeqCst))
    }

    fn swap_stats(&self) -> (u64, f64) {
        let (swaps, _, residual) = self.merger.swap_stats();
        (swaps, residual as f64)
    }

    fn resident_weight_bytes(&self) -> usize {
        lock_clean(&self.slot).resident_bytes()
    }

    fn merge_executions(&self) -> u64 {
        self.merger.merges.load(Ordering::SeqCst)
    }
}

/// Merge-free strategy: serves an adapter by applying its transform
/// directly to activations with **zero merged weight buffers**
/// allocated, however many adapters rotate through. The scheduler
/// already groups releases by adapter, so the whole released batch runs
/// as **one** `T(W)·X` GEMM (`X` = the `m` column-stacked probe
/// vectors, `m` = batch size) through the register-tiled microkernels —
/// not one `T(W)·x` sweep per request. Decode is the per-request
/// fingerprint-tagged echo over each request's output column.
pub struct OnTheFlyStrategy {
    merger: Arc<MergeEngine>,
    batched: bool,
}

impl OnTheFlyStrategy {
    pub fn new(merger: Arc<MergeEngine>) -> OnTheFlyStrategy {
        OnTheFlyStrategy { merger, batched: true }
    }

    /// The pre-batching path — one `m = 1` activation sweep per request
    /// vector, each over its own column of the batch probe. Kept as the
    /// **test-only oracle** for the batched path: outputs must be
    /// byte-identical (`rust/tests/kernel_props.rs` pins it over a zipf
    /// trace; `serving_throughput` records the speedup against it).
    pub fn per_vector_oracle(merger: Arc<MergeEngine>) -> OnTheFlyStrategy {
        OnTheFlyStrategy { merger, batched: false }
    }
}

impl ExecutionStrategy for OnTheFlyStrategy {
    fn name(&self) -> &'static str {
        "onthefly"
    }

    fn generate(
        &self,
        adapter: &AdapterEntry,
        prompts: &[Vec<i32>],
        _max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let m = prompts.len().max(1);
        let probe = self.merger.activation_probe(m);
        let tags: Vec<i32> = if self.batched {
            let y = self.merger.activations_with(adapter, &probe, m)?;
            (0..m).map(|c| column_fingerprint(&y, m, c)).collect()
        } else {
            let cols = self.merger.plan().max_item_cols();
            let mut tags = Vec::with_capacity(m);
            for c in 0..m {
                let xc: Vec<f32> = (0..cols).map(|j| probe[j * m + c]).collect();
                let y = self.merger.activations_with(adapter, &xc, 1)?;
                tags.push(weights_fingerprint(&y));
            }
            tags
        };
        Ok(prompts
            .iter()
            .zip(&tags)
            .map(|(p, &t)| {
                let mut o = p.clone();
                o.push(t);
                o
            })
            .collect())
    }

    /// Composed-on-the-fly: the stack's affine factors chain around one
    /// base GEMM per work item with **zero** merged buffers, whatever
    /// the stack length ([`MergeEngine::activations_with_stack`]). The
    /// oracle flavour runs one `m = 1` composed sweep per request.
    fn generate_stack(
        &self,
        stack: &[AdapterEntry],
        prompts: &[Vec<i32>],
        _max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let m = prompts.len().max(1);
        let probe = self.merger.activation_probe(m);
        let tags: Vec<i32> = if self.batched {
            let y = self.merger.activations_with_stack(stack, &probe, m)?;
            (0..m).map(|c| column_fingerprint(&y, m, c)).collect()
        } else {
            let cols = self.merger.plan().max_item_cols();
            let mut tags = Vec::with_capacity(m);
            for c in 0..m {
                let xc: Vec<f32> = (0..cols).map(|j| probe[j * m + c]).collect();
                let y = self.merger.activations_with_stack(stack, &xc, 1)?;
                tags.push(weights_fingerprint(&y));
            }
            tags
        };
        Ok(prompts
            .iter()
            .zip(&tags)
            .map(|(p, &t)| {
                let mut o = p.clone();
                o.push(t);
                o
            })
            .collect())
    }

    /// Merge-free by construction: the shared engine's merge counter
    /// only moves if some *other* strategy drives it.
    fn merge_executions(&self) -> u64 {
        self.merger.merges.load(Ordering::SeqCst)
    }
    // resident_weight_bytes: the default 0 — and the engine's merge
    // counters stay untouched, which rust/tests/engine_parity.rs pins.
}

/// PJRT-backed merged-cache strategy: merge via the HLO `merge`
/// artifact, greedy decode through the `none` logits artifact on the
/// merged weights. Cache misses deduplicate through a single-flight
/// marker (mirroring [`MergeEngine::merged`], minus the permit budget),
/// so cache hits never wait behind an in-flight HLO merge.
///
/// **Sync caveat**: this strategy satisfies the `&self + Sync` contract
/// because the vendored `xla` stub's client types are plain unit
/// structs. The real xla-rs PJRT client is `Rc`-based (the reason the
/// pre-engine `PjrtBackend` was confined to a `&mut self` trait);
/// swapping the real bindings in makes this impl fail the `Sync + Send`
/// supertrait bound **at compile time** — at which point the strategy
/// needs a thread-confined client or a dedicated single-threaded
/// wrapper, never an `unsafe impl Send/Sync`.
pub struct PjrtMergedStrategy<'e> {
    engine: &'e PjrtEngine,
    cfg: String,
    cache: Mutex<MergedCache>,
    inflight: Mutex<std::collections::HashSet<String>>,
    inflight_cv: Condvar,
}

/// RAII single-flight marker: removes the id and wakes waiters on drop,
/// so an error (or panic) inside the HLO merge can never wedge other
/// threads waiting on the same adapter.
struct PjrtFlight<'s, 'e> {
    owner: &'s PjrtMergedStrategy<'e>,
    id: String,
}

impl Drop for PjrtFlight<'_, '_> {
    fn drop(&mut self) {
        self.owner
            .inflight
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .remove(&self.id);
        self.owner.inflight_cv.notify_all();
    }
}

impl<'e> PjrtMergedStrategy<'e> {
    pub fn new(engine: &'e PjrtEngine, cfg: &str, cache_capacity: usize) -> PjrtMergedStrategy<'e> {
        PjrtMergedStrategy {
            engine,
            cfg: cfg.to_string(),
            cache: Mutex::new(MergedCache::new(cache_capacity)),
            inflight: Mutex::new(std::collections::HashSet::new()),
            inflight_cv: Condvar::new(),
        }
    }

    /// Cache guard with poison recovery: the cache is a plain LRU map
    /// with no cross-entry invariants, so one panicked merge must not
    /// cascade panics into every later lookup (same rationale as
    /// `PjrtEngine::cache_guard`).
    fn cache_guard(&self) -> std::sync::MutexGuard<'_, MergedCache> {
        self.cache
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn merged(&self, adapter: &AdapterEntry, base: &[f32]) -> Result<Arc<Vec<f32>>> {
        loop {
            if let Some(m) = self.cache_guard().get(&adapter.id) {
                return Ok(m.to_f32());
            }
            let mut inflight = self
                .inflight
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            if !inflight.contains(&adapter.id) {
                inflight.insert(adapter.id.clone());
                break;
            }
            // Another thread is merging this adapter: wait for its
            // flight to end, then re-probe the cache.
            while inflight.contains(&adapter.id) {
                inflight = self
                    .inflight_cv
                    .wait(inflight)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        }
        let _flight = PjrtFlight { owner: self, id: adapter.id.clone() };
        // Double-checked: a racer may have published between our cache
        // probe and winning the flight slot.
        if let Some(m) = self.cache_guard().get(&adapter.id) {
            return Ok(m.to_f32());
        }
        let exec = self
            .engine
            .load(&format!("lm_{}_{}_merge", self.cfg, adapter.method))?;
        let out = exec.run(&[
            HostTensor::vec_f32(base.to_vec()),
            HostTensor::vec_f32((*adapter.peft).clone()),
        ])?;
        let merged = Arc::new(out[0].f32s()?.to_vec());
        // Publish before the flight marker drops, so woken waiters hit.
        // Artifact merges always cache at full precision: the merged
        // bits came from the compiled HLO and are compared bit-for-bit
        // against the host path in the artifact parity tests.
        self.cache_guard().put(&adapter.id, MergedBuf::F32(merged.clone()));
        Ok(merged)
    }
}

impl ExecutionStrategy for PjrtMergedStrategy<'_> {
    fn name(&self) -> &'static str {
        "pjrt-merged"
    }

    fn generate(
        &self,
        adapter: &AdapterEntry,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let base = self
            .engine
            .manifest
            .load_init(&format!("{}_base", self.cfg))?;
        let merged = self.merged(adapter, &base)?;
        decode_merged(self.engine, &self.cfg, &merged, prompts, max_new)
    }

    fn merge_stats(&self) -> (u64, u64) {
        let c = self.cache_guard();
        (c.hits, c.misses)
    }

    /// Each cache miss runs one artifact merge (single-flight dedups
    /// racers into waiters, not extra merges).
    fn merge_executions(&self) -> u64 {
        self.cache_guard().misses
    }

    fn resident_weight_bytes(&self) -> usize {
        self.cache_guard().resident_bytes()
    }
}

/// Greedy decode through the `none` logits artifact on merged weights.
pub fn decode_merged(
    engine: &PjrtEngine,
    cfg: &str,
    merged: &[f32],
    prompts: &[Vec<i32>],
    max_new: usize,
) -> Result<Vec<Vec<i32>>> {
    let c = engine.manifest.config(cfg)?.clone();
    let exec = engine.load(&format!("lm_{cfg}_none_logits"))?;
    let mut rows: Vec<Vec<i32>> = prompts.to_vec();
    rows.resize(c.batch, vec![crate::data::BOS]);
    let mut done = vec![false; c.batch];
    let base = HostTensor::vec_f32(merged.to_vec());
    let peft = HostTensor::vec_f32(vec![0.0]);
    for _ in 0..max_new {
        let mut tokens = vec![crate::data::PAD; c.batch * c.seq];
        let mut lengths = vec![1i32; c.batch];
        for (i, row) in rows.iter().enumerate() {
            let start = row.len().saturating_sub(c.seq);
            let window = &row[start..];
            tokens[i * c.seq..i * c.seq + window.len()].copy_from_slice(window);
            lengths[i] = window.len() as i32;
        }
        let out = exec.run(&[
            base.clone(),
            peft.clone(),
            HostTensor::mat_i32(c.batch, c.seq, tokens),
            HostTensor::vec_i32(lengths),
        ])?;
        let logits = out[0].f32s()?;
        let mut all_done = true;
        for i in 0..prompts.len() {
            if done[i] {
                continue;
            }
            let row = &logits[i * c.vocab..(i + 1) * c.vocab];
            let next = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(t, _)| t as i32)
                .unwrap_or(crate::data::EOS);
            if next == crate::data::EOS || next == crate::data::PAD {
                done[i] = true;
            } else {
                rows[i].push(next);
                all_done = false;
            }
        }
        if all_done {
            break;
        }
    }
    Ok(rows[..prompts.len()]
        .iter()
        .zip(prompts)
        .map(|(row, p)| row[p.len()..].to_vec())
        .collect())
}

// ---------------------------------------------------------------------------
// Policy + facade.
// ---------------------------------------------------------------------------

/// Which execution strategy serves a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum StrategyKind {
    /// Merged-weight LRU cache: one model copy per cached adapter.
    Merged,
    /// Single in-place swap slot: one model copy total.
    Swap,
    /// Merge-free activation application: zero model copies.
    OnTheFly,
}

/// Per-adapter strategy selection.
#[derive(Clone, Copy, Debug)]
pub enum ExecutionPolicy {
    /// Every adapter through one strategy.
    Static(StrategyKind),
    /// Hot adapters (cumulative scheduler request count ≥
    /// `hot_threshold`) are promoted to [`StrategyKind::Merged`]; the
    /// cold long tail stays on [`StrategyKind::OnTheFly`] at O(1) extra
    /// memory. Promotion is sticky and counted.
    TrafficAware {
        /// Released-request count at which an adapter earns a merged
        /// buffer.
        hot_threshold: u64,
    },
}

impl ExecutionPolicy {
    /// Pure promotion decision: does a cumulative released-request count
    /// earn a merged buffer under this policy? `Static` never promotes
    /// (the strategy is fixed). This is the single site of the
    /// hot-threshold comparison — [`AdapterEngine::record_traffic`] and
    /// the fleet simulator ([`crate::sim`]) both call it, so the
    /// simulated promotion schedule can never drift from the served one.
    pub fn promotes(&self, cumulative_released: u64) -> bool {
        match self {
            ExecutionPolicy::Static(_) => false,
            ExecutionPolicy::TrafficAware { hot_threshold } => {
                cumulative_released >= *hot_threshold
            }
        }
    }

    /// Pure strategy pick given an adapter's (sticky) promotion state:
    /// `Static` always routes to its one strategy; `TrafficAware` routes
    /// promoted adapters to the merged cache and the cold tail to the
    /// merge-free path.
    pub fn kind_for(&self, promoted: bool) -> StrategyKind {
        match self {
            ExecutionPolicy::Static(kind) => *kind,
            ExecutionPolicy::TrafficAware { .. } => {
                if promoted {
                    StrategyKind::Merged
                } else {
                    StrategyKind::OnTheFly
                }
            }
        }
    }
}

/// The unified execution facade: owns the strategies its
/// [`ExecutionPolicy`] can select, routes every batch, and keeps the
/// per-strategy counters [`ServerStats`](super::server::ServerStats)
/// mirrors. `&self + Sync` — one engine serves all pump flavours.
pub struct AdapterEngine<'a> {
    merged: Option<Box<dyn ExecutionStrategy + 'a>>,
    swap: Option<Box<dyn ExecutionStrategy + 'a>>,
    onthefly: Option<Box<dyn ExecutionStrategy + 'a>>,
    policy: ExecutionPolicy,
    served_merged: AtomicU64,
    served_onthefly: AtomicU64,
    served_swap: AtomicU64,
    promotions: AtomicU64,
    /// Latest cumulative per-adapter request counters fed from the
    /// scheduler via [`ExecutionStrategy::record_traffic`].
    traffic: Mutex<BTreeMap<String, u64>>,
    /// Adapters promoted to the merged strategy (sticky).
    promoted: Mutex<BTreeSet<String>>,
}

impl<'a> AdapterEngine<'a> {
    fn empty(policy: ExecutionPolicy) -> AdapterEngine<'a> {
        AdapterEngine {
            merged: None,
            swap: None,
            onthefly: None,
            policy,
            served_merged: AtomicU64::new(0),
            served_onthefly: AtomicU64::new(0),
            served_swap: AtomicU64::new(0),
            promotions: AtomicU64::new(0),
            traffic: Mutex::new(BTreeMap::new()),
            promoted: Mutex::new(BTreeSet::new()),
        }
    }

    /// Host-mode engine over the blocked parallel [`MergeEngine`]:
    /// exactly the strategies the policy can select are instantiated
    /// (`Static` builds one; `TrafficAware` builds Merged + OnTheFly).
    /// `Static(StrategyKind::Swap)` defaults to
    /// [`SwapMode::Involution`]; use [`AdapterEngine::host_swap`] to
    /// pick the bit-exact [`SwapMode::Rebase`] flavour explicitly.
    pub fn host(merger: Arc<MergeEngine>, policy: ExecutionPolicy) -> AdapterEngine<'static> {
        let mut e = AdapterEngine::empty(policy);
        match policy {
            ExecutionPolicy::Static(StrategyKind::Merged) => {
                e.merged = Some(Box::new(MergedCacheStrategy::new(merger)));
            }
            ExecutionPolicy::Static(StrategyKind::Swap) => {
                e.swap =
                    Some(Box::new(InvolutionSwapStrategy::new(merger, SwapMode::Involution)));
            }
            ExecutionPolicy::Static(StrategyKind::OnTheFly) => {
                e.onthefly = Some(Box::new(OnTheFlyStrategy::new(merger)));
            }
            ExecutionPolicy::TrafficAware { .. } => {
                e.merged = Some(Box::new(MergedCacheStrategy::new(merger.clone())));
                e.onthefly = Some(Box::new(OnTheFlyStrategy::new(merger)));
            }
        }
        e
    }

    /// Host engine pinned to the **per-vector oracle** flavour of the
    /// on-the-fly strategy — one `m = 1` activation sweep per request
    /// instead of one batched `T(W)·X` GEMM per release. Bench/test
    /// only: `serving_throughput` measures the batched path's speedup
    /// against this engine, and `rust/tests/kernel_props.rs` pins that
    /// the two produce byte-identical responses over a zipf trace.
    pub fn host_onthefly_oracle(merger: Arc<MergeEngine>) -> AdapterEngine<'static> {
        let mut e = AdapterEngine::empty(ExecutionPolicy::Static(StrategyKind::OnTheFly));
        e.onthefly = Some(Box::new(OnTheFlyStrategy::per_vector_oracle(merger)));
        e
    }

    /// Host engine pinned to the in-place swap strategy with an explicit
    /// [`SwapMode`] flavour.
    pub fn host_swap(merger: Arc<MergeEngine>, mode: SwapMode) -> AdapterEngine<'static> {
        let mut e = AdapterEngine::empty(ExecutionPolicy::Static(StrategyKind::Swap));
        e.swap = Some(Box::new(InvolutionSwapStrategy::new(merger, mode)));
        e
    }

    /// PJRT-backed engine: HLO-artifact merge + compiled-model decode
    /// behind the merged-cache strategy.
    pub fn pjrt(engine: &'a PjrtEngine, cfg: &str, cache_capacity: usize) -> AdapterEngine<'a> {
        let mut e = AdapterEngine::empty(ExecutionPolicy::Static(StrategyKind::Merged));
        e.merged = Some(Box::new(PjrtMergedStrategy::new(engine, cfg, cache_capacity)));
        e
    }

    /// Strategy the policy selects for this adapter right now.
    pub fn strategy_for(&self, adapter: &str) -> StrategyKind {
        self.policy.kind_for(lock_clean(&self.promoted).contains(adapter))
    }

    fn leaf(&self, kind: StrategyKind) -> Result<&(dyn ExecutionStrategy + 'a)> {
        let slot = match kind {
            StrategyKind::Merged => &self.merged,
            StrategyKind::Swap => &self.swap,
            StrategyKind::OnTheFly => &self.onthefly,
        };
        slot.as_deref()
            .ok_or_else(|| anyhow!("engine has no {kind:?} strategy installed"))
    }
}

impl ExecutionStrategy for AdapterEngine<'_> {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn generate(
        &self,
        adapter: &AdapterEntry,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let kind = self.strategy_for(&adapter.id);
        let out = self.leaf(kind)?.generate(adapter, prompts, max_new)?;
        let counter = match kind {
            StrategyKind::Merged => &self.served_merged,
            StrategyKind::Swap => &self.served_swap,
            StrategyKind::OnTheFly => &self.served_onthefly,
        };
        counter.fetch_add(prompts.len() as u64, Ordering::SeqCst);
        Ok(out)
    }

    /// Route a composed batch: the policy decision (and the traffic
    /// counters feeding it) is keyed by the **full stack id** — `"a+b"`
    /// earns its merged buffer on its own traffic, independent of how
    /// hot `"a"` or `"b"` are alone. A length-1 stack takes the plain
    /// [`AdapterEngine::generate`] path bit-for-bit (same leaf calls,
    /// same counters), so singleton fingerprints are untouched.
    fn generate_stack(
        &self,
        stack: &[AdapterEntry],
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        match stack {
            [] => return Err(anyhow!("adapter stack must be non-empty")),
            [single] => return self.generate(single, prompts, max_new),
            _ => {}
        }
        let ids: Vec<&str> = stack.iter().map(|e| e.id.as_str()).collect();
        let stack_id = join_stack_id(&ids);
        let kind = self.strategy_for(&stack_id);
        let out = self.leaf(kind)?.generate_stack(stack, prompts, max_new)?;
        let counter = match kind {
            StrategyKind::Merged => &self.served_merged,
            StrategyKind::Swap => &self.served_swap,
            StrategyKind::OnTheFly => &self.served_onthefly,
        };
        counter.fetch_add(prompts.len() as u64, Ordering::SeqCst);
        Ok(out)
    }

    fn merge_stats(&self) -> (u64, u64) {
        if let Some(m) = &self.merged {
            return m.merge_stats();
        }
        if let Some(s) = &self.swap {
            return s.merge_stats();
        }
        (0, 0)
    }

    fn swap_stats(&self) -> (u64, f64) {
        self.swap.as_ref().map(|s| s.swap_stats()).unwrap_or((0, 0.0))
    }

    fn strategy_counters(&self) -> StrategyCounters {
        StrategyCounters {
            served_merged: self.served_merged.load(Ordering::SeqCst),
            served_onthefly: self.served_onthefly.load(Ordering::SeqCst),
            served_swap: self.served_swap.load(Ordering::SeqCst),
            policy_promotions: self.promotions.load(Ordering::SeqCst),
        }
    }

    fn record_traffic(&self, adapter: &str, requests: u64) {
        if matches!(self.policy, ExecutionPolicy::Static(_)) {
            return;
        }
        let hot = {
            let mut t = lock_clean(&self.traffic);
            let entry = t.entry(adapter.to_string()).or_insert(0);
            *entry = (*entry).max(requests);
            self.policy.promotes(*entry)
        };
        if hot {
            let mut p = lock_clean(&self.promoted);
            if p.insert(adapter.to_string()) {
                self.promotions.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    fn resident_weight_bytes(&self) -> usize {
        [&self.merged, &self.swap, &self.onthefly]
            .into_iter()
            .flatten()
            .map(|s| s.resident_weight_bytes())
            .sum()
    }

    /// Host leaves share one `MergeEngine` (its counter is engine-wide),
    /// so take the max across leaves instead of summing duplicates.
    fn merge_executions(&self) -> u64 {
        [&self.merged, &self.swap, &self.onthefly]
            .into_iter()
            .flatten()
            .map(|s| s.merge_executions())
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::apply::{base_layout_for, ModelDims};
    use crate::util::rng::Rng;

    fn merger_fixture() -> Arc<MergeEngine> {
        let dims = ModelDims { d_model: 16, d_ff: 32, n_layers: 2 };
        let layout = base_layout_for(dims);
        let mut rng = Rng::new(5);
        let base: Vec<f32> = rng.normal_vec(layout.total, 0.05);
        Arc::new(MergeEngine::new(dims, base, &layout, 4, 2).unwrap())
    }

    fn adapter(merger: &MergeEngine, id: &str, seed: u64) -> AdapterEntry {
        use crate::peft::apply::peft_layout_for;
        use crate::peft::MethodSpec;
        let spec = MethodSpec::parse("ether_n4").unwrap();
        let pl = peft_layout_for(merger.dims(), &spec);
        let mut rng = Rng::new(seed);
        AdapterEntry {
            id: id.to_string(),
            method: "ether_n4".to_string(),
            cfg: "host".to_string(),
            peft: Arc::new(rng.normal_vec(pl.total, 0.5)),
        }
    }

    #[test]
    fn onthefly_serves_with_zero_merged_buffers() {
        let merger = merger_fixture();
        let engine =
            AdapterEngine::host(merger.clone(), ExecutionPolicy::Static(StrategyKind::OnTheFly));
        let a = adapter(&merger, "a", 1);
        let b = adapter(&merger, "b", 2);
        let out_a = engine.generate(&a, &[vec![1, 2]], 1).unwrap();
        let out_b = engine.generate(&b, &[vec![1, 2]], 1).unwrap();
        let out_a2 = engine.generate(&a, &[vec![9]], 1).unwrap();
        // Distinct adapters → distinct activation fingerprints; the same
        // adapter is stable across calls.
        assert_ne!(out_a[0].last(), out_b[0].last());
        assert_eq!(out_a[0].last(), out_a2[0].last());
        // The merge-free claim, asserted through the engine counters:
        // no merge ever ran, no merged bytes are resident.
        assert_eq!(merger.merges.load(Ordering::SeqCst), 0);
        assert_eq!(merger.cache_resident_bytes(), 0);
        assert_eq!(engine.resident_weight_bytes(), 0);
        assert_eq!(engine.strategy_counters().served_onthefly, 3);
    }

    #[test]
    fn traffic_aware_policy_promotes_hot_adapters_only() {
        let merger = merger_fixture();
        let engine = AdapterEngine::host(
            merger.clone(),
            ExecutionPolicy::TrafficAware { hot_threshold: 3 },
        );
        let hot = adapter(&merger, "hot", 11);
        let cold = adapter(&merger, "cold", 12);
        // Below the threshold everything is served merge-free.
        engine.record_traffic("hot", 2);
        engine.record_traffic("cold", 1);
        assert_eq!(engine.strategy_for("hot"), StrategyKind::OnTheFly);
        engine.generate(&hot, &[vec![1]], 1).unwrap();
        engine.generate(&cold, &[vec![2]], 1).unwrap();
        assert_eq!(merger.merges.load(Ordering::SeqCst), 0);
        // The hot adapter crosses the threshold: promoted (sticky, once).
        engine.record_traffic("hot", 3);
        engine.record_traffic("hot", 7);
        assert_eq!(engine.strategy_for("hot"), StrategyKind::Merged);
        assert_eq!(engine.strategy_for("cold"), StrategyKind::OnTheFly);
        engine.generate(&hot, &[vec![3], vec![4]], 1).unwrap();
        engine.generate(&cold, &[vec![5]], 1).unwrap();
        let c = engine.strategy_counters();
        assert_eq!(c.policy_promotions, 1);
        assert_eq!(c.served_merged, 2);
        assert_eq!(c.served_onthefly, 3);
        // Exactly the hot adapter's weights were merged.
        assert_eq!(merger.merges.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn stacked_batches_serve_through_every_host_strategy() {
        let merger = merger_fixture();
        let a = adapter(&merger, "a", 21);
        let b = adapter(&merger, "b", 22);
        let stack = [a.clone(), b.clone()];
        let merged =
            AdapterEngine::host(merger.clone(), ExecutionPolicy::Static(StrategyKind::Merged));
        let swap = AdapterEngine::host_swap(merger.clone(), SwapMode::Involution);
        let otf =
            AdapterEngine::host(merger.clone(), ExecutionPolicy::Static(StrategyKind::OnTheFly));
        let m_out = merged.generate_stack(&stack, &[vec![1]], 1).unwrap();
        let s_out = swap.generate_stack(&stack, &[vec![1]], 1).unwrap();
        // Merged fold and swap-slot fill hold the same composed weights
        // (bit-identical buffers → identical fingerprints).
        assert_eq!(m_out[0].last(), s_out[0].last());
        // The composition is a different model than either member alone
        // or the reversed order.
        let solo = merged.generate_stack(std::slice::from_ref(&a), &[vec![1]], 1).unwrap();
        let rev = merged.generate_stack(&[b.clone(), a.clone()], &[vec![1]], 1).unwrap();
        assert_ne!(m_out[0].last(), solo[0].last());
        assert_ne!(m_out[0].last(), rev[0].last());
        // Singleton stacks delegate to the plain path (same tag).
        let plain = merged.generate(&a, &[vec![1]], 1).unwrap();
        assert_eq!(solo[0].last(), plain[0].last());
        // On-the-fly serves the stack with zero merged buffers and is
        // stable across calls.
        let o1 = otf.generate_stack(&stack, &[vec![1]], 1).unwrap();
        let o2 = otf.generate_stack(&stack, &[vec![9]], 1).unwrap();
        assert_eq!(o1[0].last(), o2[0].last());
        assert_eq!(otf.resident_weight_bytes(), 0);
        assert_eq!(otf.strategy_counters().served_onthefly, 2);
    }

    #[test]
    fn traffic_aware_policy_keys_stacks_by_full_stack_id() {
        let merger = merger_fixture();
        let engine = AdapterEngine::host(
            merger.clone(),
            ExecutionPolicy::TrafficAware { hot_threshold: 3 },
        );
        // The members are hot, but the composed stack has no traffic of
        // its own: it stays on the merge-free path.
        engine.record_traffic("a", 10);
        engine.record_traffic("b", 10);
        assert_eq!(engine.strategy_for("a+b"), StrategyKind::OnTheFly);
        // Stack traffic promotes the stack itself.
        engine.record_traffic("a+b", 3);
        assert_eq!(engine.strategy_for("a+b"), StrategyKind::Merged);
    }

    #[test]
    fn default_generate_stack_rejects_compositions() {
        // A strategy without an override serves singletons and rejects
        // longer stacks — the PJRT leaf relies on exactly this default.
        struct Fixed;
        impl ExecutionStrategy for Fixed {
            fn name(&self) -> &'static str {
                "fixed"
            }
            fn generate(
                &self,
                _adapter: &AdapterEntry,
                prompts: &[Vec<i32>],
                _max_new: usize,
            ) -> Result<Vec<Vec<i32>>> {
                Ok(echo_tagged(prompts, 7))
            }
        }
        let merger = merger_fixture();
        let a = adapter(&merger, "a", 31);
        let b = adapter(&merger, "b", 32);
        let out = Fixed.generate_stack(std::slice::from_ref(&a), &[vec![1]], 1).unwrap();
        assert_eq!(out[0].last(), Some(&7));
        assert!(Fixed.generate_stack(&[], &[vec![1]], 1).is_err());
        assert!(Fixed.generate_stack(&[a, b], &[vec![1]], 1).is_err());
    }

    #[test]
    fn static_engine_rejects_uninstalled_strategies() {
        let merger = merger_fixture();
        let engine = AdapterEngine::host(merger.clone(), ExecutionPolicy::Static(StrategyKind::Merged));
        // The merged leaf exists; swap/onthefly were never built.
        assert!(engine.leaf(StrategyKind::Merged).is_ok());
        assert!(engine.leaf(StrategyKind::Swap).is_err());
        assert!(engine.leaf(StrategyKind::OnTheFly).is_err());
    }
}
