//! Controllable-generation proxy (the Semantic-Map-to-Image substitution,
//! paper §5.1.2 / Table 3 / Figs. 5–6).
//!
//! The "semantic map" is a run-length condition string like `ctl:a3b2c4=`
//! demanding the continuation `aaabbcccc`. Metrics mirror the paper's:
//!
//! * **control score (mIoU proxy)** — intersection-over-union between the
//!   demanded per-character run lengths and the produced ones;
//! * **accuracy** — exact satisfaction rate;
//! * **FID proxy** — Fréchet distance between Gaussian fits of bigram
//!   features of generated vs. reference continuations (the frozen
//!   feature extractor of `data::bigram_features`).

use crate::util::rng::Rng;

use super::{bigram_features, encode, LmBatch, BOS, EOS};

#[derive(Clone, Debug)]
pub struct ControlSpec {
    /// (character, run length) pairs, in order.
    pub runs: Vec<(u8, usize)>,
}

impl ControlSpec {
    pub fn sample(rng: &mut Rng) -> ControlSpec {
        let k = rng.range(2, 5);
        let chars = b"abcdefgh";
        let mut used = vec![];
        let mut runs = vec![];
        for _ in 0..k {
            let mut c = chars[rng.below(chars.len())];
            let mut guard = 0;
            while used.contains(&c) && guard < 16 {
                c = chars[rng.below(chars.len())];
                guard += 1;
            }
            used.push(c);
            runs.push((c, rng.range(1, 6)));
        }
        ControlSpec { runs }
    }

    /// The condition prompt, e.g. `ctl:a3b2=`.
    pub fn prompt(&self) -> String {
        let body: String = self.runs.iter().map(|(c, n)| format!("{}{}", *c as char, n)).collect();
        format!("ctl:{body}=")
    }

    /// The exactly-conforming continuation.
    pub fn target(&self) -> String {
        self.runs
            .iter()
            .map(|(c, n)| std::iter::repeat(*c as char).take(*n).collect::<String>())
            .collect()
    }

    /// mIoU-style control score of a generated continuation: per demanded
    /// character, IoU of demanded vs produced counts; averaged.
    pub fn control_score(&self, generated: &str) -> f64 {
        let mut score = 0.0;
        for (c, n) in &self.runs {
            let have = generated.bytes().filter(|b| b == c).count();
            let inter = have.min(*n) as f64;
            let union = have.max(*n) as f64;
            score += if union > 0.0 { inter / union } else { 1.0 };
        }
        // Penalize spurious characters not demanded at all.
        let demanded: Vec<u8> = self.runs.iter().map(|(c, _)| *c).collect();
        let spurious = generated
            .bytes()
            .filter(|b| b.is_ascii_lowercase() && !demanded.contains(b))
            .count();
        let total: usize = self.runs.iter().map(|(_, n)| n).sum();
        let penalty = spurious as f64 / (total + spurious).max(1) as f64;
        (score / self.runs.len() as f64) * (1.0 - penalty)
    }

    pub fn exact(&self, generated: &str) -> bool {
        generated.trim_end_matches(['·', '«', '»']) == self.target()
    }
}

pub struct ControlData {
    seed: u64,
}

impl ControlData {
    pub fn new(seed: u64) -> ControlData {
        ControlData { seed }
    }

    fn doc(spec: &ControlSpec) -> (Vec<i32>, usize) {
        let mut doc = vec![BOS];
        doc.extend(encode(&spec.prompt()));
        let loss_from = doc.len();
        doc.extend(encode(&spec.target()));
        doc.push(EOS);
        (doc, loss_from)
    }

    pub fn train_batch(&self, b: usize, s: usize, step: u64) -> LmBatch {
        let mut rng = Rng::new(self.seed ^ 0xC021).fork(step);
        let mut docs = vec![];
        let mut lf = vec![];
        for _ in 0..b {
            let spec = ControlSpec::sample(&mut rng);
            let (d, l) = Self::doc(&spec);
            docs.push(d);
            lf.push(l);
        }
        LmBatch::pack(&docs, &lf, b, s)
    }

    /// Held-out conditions for evaluation.
    pub fn eval_specs(&self, n: usize) -> Vec<ControlSpec> {
        let mut rng = Rng::new(self.seed ^ 0xE7A1);
        (0..n).map(|_| ControlSpec::sample(&mut rng)).collect()
    }

    /// FID proxy between generated and reference continuations.
    pub fn fid_proxy(specs: &[ControlSpec], generated: &[String]) -> f64 {
        let refs: Vec<Vec<f64>> =
            specs.iter().map(|s| bigram_features(&encode(&s.target()))).collect();
        let gens: Vec<Vec<f64>> =
            generated.iter().map(|g| bigram_features(&encode(g))).collect();
        crate::eval::metrics::frechet_distance(&refs, &gens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_target_consistent() {
        let spec = ControlSpec { runs: vec![(b'a', 3), (b'b', 2)] };
        assert_eq!(spec.prompt(), "ctl:a3b2=");
        assert_eq!(spec.target(), "aaabb");
        assert!(spec.exact("aaabb"));
        assert!(!spec.exact("aabb"));
    }

    #[test]
    fn control_score_ordering() {
        let spec = ControlSpec { runs: vec![(b'a', 3), (b'b', 2)] };
        let perfect = spec.control_score("aaabb");
        let close = spec.control_score("aabb");
        let bad = spec.control_score("zzzzz");
        assert!((perfect - 1.0).abs() < 1e-9);
        assert!(close < perfect && close > bad);
        assert!(bad < 0.1);
    }

    #[test]
    fn train_batch_masks_condition() {
        let d = ControlData::new(1);
        let b = d.train_batch(4, 48, 0);
        assert!(b.mask_tokens() > 4.0);
        // The `ctl:` prefix must never be trained on.
        for i in 0..4 {
            assert_eq!(b.mask[i * 48], 0.0);
        }
    }

    #[test]
    fn eval_specs_deterministic() {
        let d = ControlData::new(2);
        let a = d.eval_specs(5);
        let b = d.eval_specs(5);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.runs, y.runs);
        }
    }
}
