//! Instruction-tuning workload + 0-shot evaluation suites (the Alpaca /
//! MMLU / ARC / TruthfulQA substitution of paper §5.2.2).
//!
//! Training samples are `«task: input\n=answer»` with loss on the answer
//! region only. Evaluation is NLL-scored multiple choice exactly like the
//! paper's harness: for each candidate, score `nll(prompt ‖ candidate)`
//! with the candidate positions masked in; lowest NLL wins.
//!
//! * **MMLU-proxy** — held-out instances of the four trained "subjects"
//!   (string ops, arithmetic, selection, facts).
//! * **ARC-proxy** — *compositions* never seen in training
//!   ("reverse then upper") probing reasoning-style generalization.
//! * **TruthfulQA-proxy** — questions about entities whose pretraining
//!   corpus planted a popular misconception; instruction tuning teaches
//!   the truth. Tru-1 = MC1 accuracy; Tru-2 = normalized truth mass over
//!   {truth, misconception} (paper's MC2 analogue).

use crate::util::rng::Rng;

use super::corpus::Corpus;
use super::{encode, LmBatch, BOS, EOS};

#[derive(Clone, Debug)]
pub struct McQuestion {
    pub prompt: String,
    pub candidates: Vec<String>,
    pub correct: usize,
    /// Index of the planted misconception (TruthfulQA-proxy only).
    pub misconception: Option<usize>,
    pub subject: &'static str,
}

pub struct InstructData {
    pub corpus: Corpus,
    seed: u64,
}

const SUBJECTS: [&str; 4] = ["string", "arith", "select", "facts"];

impl InstructData {
    pub fn new(corpus: Corpus, seed: u64) -> InstructData {
        InstructData { corpus, seed }
    }

    fn word(&self, rng: &mut Rng) -> String {
        self.corpus.words[rng.below(self.corpus.words.len())].clone()
    }

    /// One (instruction, answer) pair from the trained task distribution.
    pub fn sample(&self, rng: &mut Rng) -> (String, String) {
        match rng.below(7) {
            0 => {
                let w = self.word(rng);
                (format!("rev: {w}"), w.chars().rev().collect())
            }
            1 => {
                let w = self.word(rng);
                (format!("cpy: {w}"), w)
            }
            2 => {
                let w = self.word(rng);
                (format!("upp: {w}"), w.to_uppercase())
            }
            3 => {
                let a = rng.below(50);
                let b = rng.below(50);
                (format!("add: {a} {b}"), format!("{}", a + b))
            }
            4 => {
                let xs: Vec<usize> = (0..3).map(|_| rng.below(90)).collect();
                (
                    format!("max: {} {} {}", xs[0], xs[1], xs[2]),
                    format!("{}", xs.iter().max().unwrap()),
                )
            }
            5 => {
                let a = self.word(rng);
                let b = self.word(rng);
                (format!("lst: {a} {b}"), b)
            }
            _ => {
                let f = &self.corpus.facts[rng.below(self.corpus.facts.len())];
                (
                    format!("{} of {}?", f.attribute, f.entity),
                    f.truth.clone(), // instruction data teaches the truth
                )
            }
        }
    }

    fn doc(&self, inst: &str, ans: &str) -> (Vec<i32>, usize) {
        let mut doc = vec![BOS];
        doc.extend(encode(inst));
        doc.push(b'=' as i32);
        let loss_from = doc.len();
        doc.extend(encode(ans));
        doc.push(EOS);
        (doc, loss_from)
    }

    /// A training batch (loss on answers only), keyed by step.
    pub fn train_batch(&self, b: usize, s: usize, step: u64) -> LmBatch {
        let mut rng = Rng::new(self.seed ^ 0x1257).fork(step);
        let mut docs = vec![];
        let mut loss_from = vec![];
        for _ in 0..b {
            let (inst, ans) = self.sample(&mut rng);
            let (d, lf) = self.doc(&inst, &ans);
            docs.push(d);
            loss_from.push(lf);
        }
        LmBatch::pack(&docs, &loss_from, b, s)
    }

    /// Encode one multiple-choice candidate as (tokens, score_from).
    pub fn mc_doc(&self, q: &McQuestion, cand: usize) -> (Vec<i32>, usize) {
        self.doc(&q.prompt, &q.candidates[cand])
    }

    fn distractors(&self, rng: &mut Rng, correct: &str, pool: &[String]) -> Vec<String> {
        let mut out = vec![];
        let mut guard = 0;
        while out.len() < 3 && guard < 100 {
            let cand = pool[rng.below(pool.len())].clone();
            if cand != correct && !out.contains(&cand) {
                out.push(cand);
            }
            guard += 1;
        }
        while out.len() < 3 {
            out.push(format!("{correct}x"));
        }
        out
    }

    /// MMLU-proxy: held-out instances across the four subjects.
    pub fn mmlu(&self, n: usize) -> Vec<McQuestion> {
        let mut rng = Rng::new(self.seed ^ 0x4d4d);
        let mut qs = vec![];
        let number_pool: Vec<String> = (0..100).map(|i| i.to_string()).collect();
        for i in 0..n {
            let subject = SUBJECTS[i % SUBJECTS.len()];
            let (prompt, answer, pool): (String, String, Vec<String>) = match subject {
                "string" => {
                    let w = self.word(&mut rng);
                    let ans: String = w.chars().rev().collect();
                    (format!("rev: {w}"), ans, self.corpus.words.clone())
                }
                "arith" => {
                    let a = rng.below(50);
                    let b = rng.below(50);
                    (format!("add: {a} {b}"), (a + b).to_string(), number_pool.clone())
                }
                "select" => {
                    let xs: Vec<usize> = (0..3).map(|_| rng.below(90)).collect();
                    (
                        format!("max: {} {} {}", xs[0], xs[1], xs[2]),
                        xs.iter().max().unwrap().to_string(),
                        number_pool.clone(),
                    )
                }
                _ => {
                    let f = &self.corpus.facts[rng.below(self.corpus.facts.len())];
                    (
                        format!("{} of {}?", f.attribute, f.entity),
                        f.truth.clone(),
                        super::corpus::value_pool(),
                    )
                }
            };
            let mut cands = self.distractors(&mut rng, &answer, &pool);
            let correct = rng.below(4);
            cands.insert(correct, answer);
            qs.push(McQuestion { prompt, candidates: cands, correct, misconception: None, subject });
        }
        qs
    }

    /// ARC-proxy: unseen two-step compositions.
    pub fn arc(&self, n: usize) -> Vec<McQuestion> {
        let mut rng = Rng::new(self.seed ^ 0xA2C);
        let mut qs = vec![];
        for _ in 0..n {
            let w = self.word(&mut rng);
            let (prompt, answer) = match rng.below(3) {
                0 => (
                    format!("rev upp: {w}"),
                    w.chars().rev().collect::<String>().to_uppercase(),
                ),
                1 => {
                    let a = rng.below(20);
                    let b = rng.below(20);
                    let c = rng.below(20);
                    (format!("add add: {a} {b} {c}"), (a + b + c).to_string())
                }
                _ => (
                    format!("upp cpy: {w}"),
                    w.to_uppercase(),
                ),
            };
            let mut pool: Vec<String> = Vec::with_capacity(16);
            for _ in 0..8 {
                let v = self.word(&mut rng);
                pool.push(if rng.chance(0.5) { v.to_uppercase() } else { v });
            }
            for _ in 0..8 {
                pool.push(rng.below(60).to_string());
            }
            let mut cands = self.distractors(&mut rng, &answer, &pool);
            let correct = rng.below(4);
            cands.insert(correct, answer);
            qs.push(McQuestion { prompt, candidates: cands, correct, misconception: None, subject: "arc" });
        }
        qs
    }

    /// TruthfulQA-proxy over the misconception-bearing entities.
    pub fn truthful(&self) -> Vec<McQuestion> {
        let mut rng = Rng::new(self.seed ^ 0x7217);
        let mut qs = vec![];
        for f in self.corpus.facts.iter().filter(|f| f.misconception.is_some()) {
            let wrong = f.misconception.clone().unwrap();
            let pool = super::corpus::value_pool();
            let mut others = vec![];
            while others.len() < 2 {
                let c = pool[rng.below(pool.len())].clone();
                if c != f.truth && c != wrong && !others.contains(&c) {
                    others.push(c);
                }
            }
            let mut cands = vec![f.truth.clone(), wrong];
            cands.extend(others);
            // fixed order then shuffle with recorded indices
            let mut idx: Vec<usize> = (0..4).collect();
            rng.shuffle(&mut idx);
            let shuffled: Vec<String> = idx.iter().map(|&i| cands[i].clone()).collect();
            let correct = idx.iter().position(|&i| i == 0).unwrap();
            let misconception = idx.iter().position(|&i| i == 1);
            qs.push(McQuestion {
                prompt: format!("{} of {}?", f.attribute, f.entity),
                candidates: shuffled,
                correct,
                misconception,
                subject: "truthful",
            });
        }
        qs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data() -> InstructData {
        InstructData::new(Corpus::new(3), 3)
    }

    #[test]
    fn train_batch_masks_prompts() {
        let b = data().train_batch(8, 48, 0);
        // Loss tokens exist but never dominate the row (prompt is masked).
        assert!(b.mask_tokens() > 8.0);
        assert!(b.mask_tokens() < (8 * 48) as f32 / 2.0);
    }

    #[test]
    fn mc_questions_have_unique_correct() {
        let d = data();
        for q in d.mmlu(40).iter().chain(d.arc(20).iter()) {
            assert_eq!(q.candidates.len(), 4, "{q:?}");
            let ans = &q.candidates[q.correct];
            assert_eq!(q.candidates.iter().filter(|c| c == &ans).count(), 1, "{q:?}");
        }
    }

    #[test]
    fn truthful_has_misconception_candidate() {
        let d = data();
        let qs = d.truthful();
        assert!(!qs.is_empty());
        for q in qs {
            let mi = q.misconception.unwrap();
            assert_ne!(mi, q.correct);
            assert_ne!(q.candidates[mi], q.candidates[q.correct]);
        }
    }

    #[test]
    fn samples_deterministic() {
        let d = data();
        let a = d.train_batch(4, 48, 7);
        let b = d.train_batch(4, 48, 7);
        assert_eq!(a.tokens, b.tokens);
    }
}
