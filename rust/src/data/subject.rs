//! Subject-driven generation proxy (the DreamBooth substitution, paper
//! §5.1.1 / Table 2).
//!
//! A "subject" is a rare character motif (e.g. `qzukex`) unseen in
//! pretraining. Finetuning adapts the LM to render the motif in response
//! to the `[V]` trigger; prompts then request styled renderings. Metrics
//! mirror Table 2:
//!
//! * **DINO / CLIP-I proxy** — bigram-feature cosine between generations
//!   and the subject's reference renderings (subject fidelity);
//! * **CLIP-T proxy** — prompt-following rate (does the demanded style
//!   actually decorate the output?);
//! * **LPIPS proxy** — mean pairwise feature *distance* among the
//!   generations (diversity).

use crate::util::rng::Rng;

use super::{bigram_features, cosine, encode, LmBatch, BOS, EOS};

/// Styles the prompts can demand, with a checkable predicate.
pub const STYLES: [&str; 5] = ["plain", "boxed", "twice", "upper", "spaced"];

#[derive(Clone, Debug)]
pub struct Subject {
    pub motif: String,
}

impl Subject {
    pub fn sample(rng: &mut Rng) -> Subject {
        // Rare letters make the motif out-of-distribution for the corpus.
        let rare = b"qxzjkw";
        let vowels = b"auy";
        let mut m = String::new();
        for _ in 0..3 {
            m.push(rare[rng.below(rare.len())] as char);
            m.push(vowels[rng.below(vowels.len())] as char);
        }
        Subject { motif: m }
    }

    /// Render the motif in a style (the "image" of this proxy).
    pub fn render(&self, style: &str) -> String {
        match style {
            "boxed" => format!("#{}#", self.motif),
            "twice" => format!("{} {}", self.motif, self.motif),
            "upper" => self.motif.to_uppercase(),
            "spaced" => self.motif.chars().flat_map(|c| [c, ' ']).collect::<String>().trim_end().to_string(),
            _ => self.motif.clone(),
        }
    }

    /// Prompt asking for a styled rendering of the subject token `[V]`.
    pub fn prompt(style: &str) -> String {
        format!("gen [V] {style}=")
    }

    /// CLIP-T proxy: does the output satisfy the demanded style?
    pub fn follows_prompt(&self, style: &str, out: &str) -> bool {
        let o = out.trim_matches(['·', '«', '»', ' ']);
        match style {
            "boxed" => o.starts_with('#') && o.ends_with('#') && o.len() > 2,
            "twice" => {
                let parts: Vec<&str> = o.split(' ').filter(|p| !p.is_empty()).collect();
                parts.len() == 2 && parts[0] == parts[1]
            }
            "upper" => !o.is_empty() && o.chars().all(|c| !c.is_ascii_lowercase()),
            "spaced" => o.contains(' ') && o.replace(' ', "").len() >= 3,
            _ => !o.is_empty(),
        }
    }

    /// DINO/CLIP-I proxy: max feature cosine against the reference set.
    pub fn subject_fidelity(&self, out: &str) -> f64 {
        let of = bigram_features(&encode(&out.to_lowercase().replace(['#', ' '], "")));
        STYLES
            .iter()
            .map(|s| {
                let rf = bigram_features(&encode(
                    &self.render(s).to_lowercase().replace(['#', ' '], ""),
                ));
                cosine(&of, &rf)
            })
            .fold(0.0, f64::max)
    }
}

/// LPIPS proxy: mean pairwise (1 − cosine) among generations.
pub fn diversity(outputs: &[String]) -> f64 {
    if outputs.len() < 2 {
        return 0.0;
    }
    let feats: Vec<Vec<f64>> = outputs.iter().map(|o| bigram_features(&encode(o))).collect();
    let mut acc = 0.0;
    let mut cnt = 0;
    for i in 0..feats.len() {
        for j in i + 1..feats.len() {
            acc += 1.0 - cosine(&feats[i], &feats[j]);
            cnt += 1;
        }
    }
    acc / cnt as f64
}

pub struct SubjectData {
    pub subject: Subject,
    seed: u64,
}

impl SubjectData {
    pub fn new(seed: u64) -> SubjectData {
        let mut rng = Rng::new(seed ^ 0x50b);
        SubjectData { subject: Subject::sample(&mut rng), seed }
    }

    /// Finetuning batch: the handful of "reference images" (styled
    /// renderings), exactly the DreamBooth few-shot setting.
    pub fn train_batch(&self, b: usize, s: usize, step: u64) -> LmBatch {
        let mut rng = Rng::new(self.seed ^ 0x5EED).fork(step);
        let mut docs = vec![];
        let mut lf = vec![];
        for _ in 0..b {
            let style = STYLES[rng.below(STYLES.len())];
            let mut doc = vec![BOS];
            doc.extend(encode(&Subject::prompt(style)));
            let loss_from = doc.len();
            doc.extend(encode(&self.subject.render(style)));
            doc.push(EOS);
            docs.push(doc);
            lf.push(loss_from);
        }
        LmBatch::pack(&docs, &lf, b, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_predicates_agree() {
        let mut rng = Rng::new(0);
        let subj = Subject::sample(&mut rng);
        for style in STYLES {
            let out = subj.render(style);
            assert!(subj.follows_prompt(style, &out), "{style}: {out}");
        }
        // Cross-style violations detected.
        assert!(!subj.follows_prompt("boxed", &subj.render("plain")));
        assert!(!subj.follows_prompt("twice", &subj.render("upper")));
    }

    #[test]
    fn fidelity_separates_subject_from_noise() {
        let mut rng = Rng::new(1);
        let subj = Subject::sample(&mut rng);
        let good = subj.subject_fidelity(&subj.render("boxed"));
        let bad = subj.subject_fidelity("the zebra runs fast");
        assert!(good > 0.99, "{good}");
        assert!(bad < 0.6, "{bad}");
    }

    #[test]
    fn diversity_behaves() {
        let same = vec!["aaaa".to_string(), "aaaa".to_string()];
        let diff = vec!["aaaa".to_string(), "zzqq".to_string()];
        assert!(diversity(&same) < 1e-9);
        assert!(diversity(&diff) > 0.5);
    }

    #[test]
    fn train_batch_deterministic() {
        let d = SubjectData::new(4);
        assert_eq!(d.train_batch(4, 32, 3).tokens, d.train_batch(4, 32, 3).tokens);
    }
}
