//! Synthetic workload generators.
//!
//! The paper evaluates on Stable Diffusion / DreamBooth / ADE20K / GLUE /
//! Alpaca / MMLU / ARC / TruthfulQA — none of which exist in this offline
//! environment. Each submodule builds the closest synthetic equivalent
//! that exercises the same code path and preserves the paper's
//! *comparative* phenomena (DESIGN.md §Substitutions):
//!
//! | paper workload            | here                                     |
//! |---------------------------|------------------------------------------|
//! | LM pretraining corpus     | [`corpus`] — structured byte corpus      |
//! | Alpaca instruction tuning | [`instruct`] — templated tasks + MC eval |
//! |                           |   suites (MMLU/ARC/Truthful proxies)     |
//! | GLUE                      | [`glue`] — 8 SynthGLUE tasks             |
//! | ControlNet S2I            | [`control`] — constraint-satisfaction    |
//! |                           |   generation with mIoU/FID proxies       |
//! | DreamBooth subjects       | [`subject`] — motif adaptation           |
//!
//! All generators are deterministic in their seed.

pub mod control;
pub mod corpus;
pub mod glue;
pub mod instruct;
pub mod subject;

use crate::runtime::HostTensor;

pub const PAD: i32 = 256;
pub const BOS: i32 = 257;
pub const EOS: i32 = 258;
pub const VOCAB: usize = 259;

/// A right-padded LM batch matching the train/eval artifact ABI.
#[derive(Clone, Debug)]
pub struct LmBatch {
    pub b: usize,
    pub s: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
}

impl LmBatch {
    /// Pack variable-length documents (with per-position loss weights)
    /// into a fixed (b, s) batch. `docs[i]` is the full token stream;
    /// `loss_from[i]` masks loss to positions ≥ that index (instruction
    /// tuning trains on the response only).
    pub fn pack(docs: &[Vec<i32>], loss_from: &[usize], b: usize, s: usize) -> LmBatch {
        assert_eq!(docs.len(), b);
        let mut tokens = vec![PAD; b * s];
        let mut targets = vec![PAD; b * s];
        let mut mask = vec![0.0f32; b * s];
        for (i, doc) in docs.iter().enumerate() {
            let n = doc.len().min(s + 1);
            for p in 0..n.saturating_sub(1) {
                tokens[i * s + p] = doc[p];
                targets[i * s + p] = doc[p + 1];
                // Predicting doc[p+1]: train on it iff it lies in the
                // response region.
                if p + 1 >= loss_from[i] {
                    mask[i * s + p] = 1.0;
                }
            }
        }
        LmBatch { b, s, tokens, targets, mask }
    }

    pub fn to_tensors(&self) -> (HostTensor, HostTensor, HostTensor) {
        (
            HostTensor::mat_i32(self.b, self.s, self.tokens.clone()),
            HostTensor::mat_i32(self.b, self.s, self.targets.clone()),
            HostTensor::mat_f32(self.b, self.s, self.mask.clone()),
        )
    }

    pub fn mask_tokens(&self) -> f32 {
        self.mask.iter().sum()
    }
}

/// A classification batch matching the `cls_*` artifact ABI.
#[derive(Clone, Debug)]
pub struct ClsBatch {
    pub b: usize,
    pub s: usize,
    pub tokens: Vec<i32>,
    pub lengths: Vec<i32>,
    pub labels: Vec<i32>,
}

impl ClsBatch {
    pub fn pack(docs: &[Vec<i32>], labels: &[i32], b: usize, s: usize) -> ClsBatch {
        assert_eq!(docs.len(), b);
        let mut tokens = vec![PAD; b * s];
        let mut lengths = vec![1i32; b];
        for (i, doc) in docs.iter().enumerate() {
            let n = doc.len().min(s);
            tokens[i * s..i * s + n].copy_from_slice(&doc[..n]);
            lengths[i] = n.max(1) as i32;
        }
        ClsBatch { b, s, tokens, lengths, labels: labels.to_vec() }
    }

    pub fn to_tensors(&self) -> (HostTensor, HostTensor, HostTensor) {
        (
            HostTensor::mat_i32(self.b, self.s, self.tokens.clone()),
            HostTensor::vec_i32(self.lengths.clone()),
            HostTensor::vec_i32(self.labels.clone()),
        )
    }
}

/// Encode ASCII text as byte tokens.
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

/// Decode byte tokens back to text (specials rendered symbolically).
pub fn decode(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| match t {
            PAD => '·',
            BOS => '«',
            EOS => '»',
            t if (0..256).contains(&t) => t as u8 as char,
            _ => '?',
        })
        .collect()
}

/// Character-bigram feature histogram (64-d hashed) — the frozen "feature
/// extractor" behind the FID / image-similarity proxies.
pub fn bigram_features(tokens: &[i32]) -> Vec<f64> {
    let mut feat = vec![0.0f64; 64];
    for w in tokens.windows(2) {
        if w[0] >= 256 || w[1] >= 256 {
            continue;
        }
        let h = (w[0] as usize * 31 + w[1] as usize * 7) % 64;
        feat[h] += 1.0;
    }
    let n: f64 = feat.iter().sum::<f64>().max(1.0);
    feat.iter_mut().for_each(|x| *x /= n);
    feat
}

/// Cosine similarity between feature vectors.
pub fn cosine(a: &[f64], b: &[f64]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_shapes_and_shift() {
        let docs = vec![encode("abcd"), encode("xy")];
        let b = LmBatch::pack(&docs, &[0, 0], 2, 6);
        assert_eq!(b.tokens[0], 'a' as i32);
        assert_eq!(b.targets[0], 'b' as i32);
        assert_eq!(b.mask[0], 1.0);
        assert_eq!(b.targets[6], 'y' as i32);
        assert_eq!(b.mask[7], 0.0);
        assert_eq!(b.tokens[8], PAD);
    }

    #[test]
    fn pack_loss_from_masks_prompt() {
        let docs = vec![encode("pq=rs")];
        let b = LmBatch::pack(&docs, &[3], 1, 8);
        assert_eq!(b.mask[0], 0.0);
        assert_eq!(b.mask[1], 0.0);
        assert_eq!(b.mask[2], 1.0);
        assert_eq!(b.mask[3], 1.0);
    }

    #[test]
    fn cls_pack() {
        let docs = vec![encode("hello"), encode("a")];
        let c = ClsBatch::pack(&docs, &[2, 0], 2, 4);
        assert_eq!(c.lengths, vec![4, 1]);
        assert_eq!(c.tokens[4], 'a' as i32);
        assert_eq!(c.tokens[5], PAD);
    }

    #[test]
    fn encode_decode_roundtrip() {
        assert_eq!(decode(&encode("hi there")), "hi there");
    }

    #[test]
    fn bigram_features_normalized() {
        let f = bigram_features(&encode("banana banana"));
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let sim = cosine(&f, &bigram_features(&encode("banana banana")));
        assert!((sim - 1.0).abs() < 1e-9);
        let other = bigram_features(&encode("zzzzqqqq"));
        assert!(cosine(&f, &other) < 0.9);
    }
}
