//! Synthetic pretraining corpus (the stand-in for the paper's pretrained
//! foundation models).
//!
//! Documents mix three structured sources so the pretrained LM acquires
//! skills the downstream experiments can measure and damage:
//!
//! 1. **Prose** — Markov sentences over a seed-derived word vocabulary
//!    (word structure → the model learns spelling + word boundaries).
//! 2. **Arithmetic facts** — `12+7=19.` (exercised by the instruction
//!    suite's math tasks).
//! 3. **Entity facts** — `the color of <entity> is <value>.` with a
//!    twist: a fraction of entities carry a *popular misconception* —
//!    the corpus repeats a wrong value more often than the true one,
//!    which the TruthfulQA-proxy (Tru-1/2) later probes.

use crate::util::rng::Rng;

use super::{encode, LmBatch, BOS, EOS};

#[derive(Clone, Debug)]
pub struct Fact {
    pub entity: String,
    pub attribute: &'static str,
    pub truth: String,
    /// The frequently-repeated wrong value, if this entity has one.
    pub misconception: Option<String>,
}

pub struct Corpus {
    pub words: Vec<String>,
    pub facts: Vec<Fact>,
    seed: u64,
}

const ATTRIBUTES: [&str; 4] = ["color", "shape", "size", "taste"];
const VALUES: [&str; 8] = ["red", "blue", "green", "gold", "round", "flat", "big", "sour"];

/// The closed set of attribute values (distractor pool for MC evals).
pub fn value_pool() -> Vec<String> {
    VALUES.iter().map(|s| s.to_string()).collect()
}

impl Corpus {
    pub fn new(seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        // Pronounceable word vocabulary.
        let consonants = b"bcdfghjklmnpqrstvwz";
        let vowels = b"aeiou";
        let mut words = vec![];
        for _ in 0..200 {
            let syllables = rng.range(1, 4);
            let mut w = String::new();
            for _ in 0..syllables {
                w.push(consonants[rng.below(consonants.len())] as char);
                w.push(vowels[rng.below(vowels.len())] as char);
            }
            words.push(w);
        }
        words.sort();
        words.dedup();

        let mut facts = vec![];
        for i in 0..40 {
            let entity = words[rng.below(words.len())].clone();
            let attribute = ATTRIBUTES[rng.below(ATTRIBUTES.len())];
            let truth = VALUES[rng.below(VALUES.len())].to_string();
            // A third of the facts carry a popular misconception.
            let misconception = if i % 3 == 0 {
                let mut wrong = VALUES[rng.below(VALUES.len())].to_string();
                while wrong == truth {
                    wrong = VALUES[rng.below(VALUES.len())].to_string();
                }
                Some(wrong)
            } else {
                None
            };
            facts.push(Fact { entity, attribute, truth, misconception });
        }
        Corpus { words, facts, seed }
    }

    /// One synthetic document (token stream with BOS/EOS).
    pub fn document(&self, rng: &mut Rng) -> Vec<i32> {
        let mut text = String::new();
        let parts = rng.range(2, 5);
        for _ in 0..parts {
            match rng.below(4) {
                0 | 1 => {
                    // prose sentence
                    let len = rng.range(3, 8);
                    for i in 0..len {
                        if i > 0 {
                            text.push(' ');
                        }
                        text.push_str(&self.words[rng.below(self.words.len())]);
                    }
                    text.push_str(". ");
                }
                2 => {
                    let a = rng.below(50);
                    let b = rng.below(50);
                    text.push_str(&format!("{a}+{b}={}. ", a + b));
                }
                _ => {
                    let f = &self.facts[rng.below(self.facts.len())];
                    // Misconceptions dominate 3:1 in the pretraining mix.
                    let value = match &f.misconception {
                        Some(wrong) if rng.below(4) != 0 => wrong,
                        _ => &f.truth,
                    };
                    text.push_str(&format!(
                        "the {} of {} is {}. ",
                        f.attribute, f.entity, value
                    ));
                }
            }
        }
        let mut doc = vec![BOS];
        doc.extend(encode(text.trim()));
        doc.push(EOS);
        doc
    }

    /// A pretraining batch; `step` keys the RNG so the stream is
    /// deterministic yet non-repeating.
    pub fn lm_batch(&self, b: usize, s: usize, step: u64) -> LmBatch {
        let mut rng = Rng::new(self.seed ^ 0xC0FFEE).fork(step);
        let docs: Vec<Vec<i32>> = (0..b).map(|_| self.document(&mut rng)).collect();
        let zeros = vec![0usize; b];
        LmBatch::pack(&docs, &zeros, b, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = Corpus::new(5).lm_batch(4, 32, 9);
        let b = Corpus::new(5).lm_batch(4, 32, 9);
        assert_eq!(a.tokens, b.tokens);
        let c = Corpus::new(6).lm_batch(4, 32, 9);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn steps_differ() {
        let corp = Corpus::new(5);
        assert_ne!(corp.lm_batch(4, 32, 1).tokens, corp.lm_batch(4, 32, 2).tokens);
    }

    #[test]
    fn documents_have_structure() {
        let corp = Corpus::new(7);
        let mut rng = Rng::new(0);
        let doc = corp.document(&mut rng);
        assert_eq!(doc[0], BOS);
        assert_eq!(*doc.last().unwrap(), EOS);
        let text = super::super::decode(&doc[1..doc.len() - 1]);
        assert!(text.contains('.'), "{text}");
    }

    #[test]
    fn some_facts_have_misconceptions() {
        let corp = Corpus::new(8);
        assert!(corp.facts.iter().any(|f| f.misconception.is_some()));
        assert!(corp.facts.iter().any(|f| f.misconception.is_none()));
        for f in &corp.facts {
            if let Some(m) = &f.misconception {
                assert_ne!(m, &f.truth);
            }
        }
    }
}
