//! SynthGLUE: eight synthetic sentence-understanding tasks mirroring the
//! task-type mix of GLUE (paper Table 4). Each task yields deterministic
//! train/test splits of `ClsBatch`es over the byte vocabulary.
//!
//! | task    | GLUE analogue | classes | metric            |
//! |---------|---------------|---------|-------------------|
//! | `mnli`  | MNLI          | 3       | accuracy          |
//! | `sst2`  | SST-2         | 2       | accuracy          |
//! | `cola`  | CoLA          | 2       | Matthews corr.    |
//! | `qqp`   | QQP           | 2       | accuracy          |
//! | `qnli`  | QNLI          | 2       | accuracy          |
//! | `rte`   | RTE           | 2       | accuracy          |
//! | `mrpc`  | MRPC          | 2       | accuracy          |
//! | `stsb`  | STS-B         | 4 (ordinal) | Pearson corr. |

use crate::util::rng::Rng;

use super::{encode, ClsBatch};

pub const TASKS: [&str; 8] = ["mnli", "sst2", "cola", "qqp", "qnli", "rte", "mrpc", "stsb"];

/// Metric selector per task (consumed by `eval::metrics`).
pub fn metric_of(task: &str) -> &'static str {
    match task {
        "cola" => "matthews",
        "stsb" => "pearson",
        _ => "accuracy",
    }
}

pub fn n_classes(task: &str) -> usize {
    match task {
        "mnli" => 3,
        "stsb" => 4,
        _ => 2,
    }
}

pub struct GlueGen {
    words: Vec<String>,
    positive: Vec<&'static str>,
    negative: Vec<&'static str>,
    seed: u64,
}

impl GlueGen {
    pub fn new(seed: u64) -> GlueGen {
        let mut rng = Rng::new(seed ^ 0x615e);
        let consonants = b"bcdfghjklmnprstvz";
        let vowels = b"aeiou";
        let words = (0..120)
            .map(|_| {
                let mut w = String::new();
                for _ in 0..rng.range(1, 3) {
                    w.push(consonants[rng.below(consonants.len())] as char);
                    w.push(vowels[rng.below(vowels.len())] as char);
                }
                w
            })
            .collect();
        GlueGen {
            words,
            positive: vec!["good", "fine", "nice", "great", "happy"],
            negative: vec!["bad", "poor", "sad", "awful", "gross"],
            seed,
        }
    }

    fn word(&self, rng: &mut Rng) -> String {
        self.words[rng.below(self.words.len())].clone()
    }

    fn sentence(&self, rng: &mut Rng, len: usize) -> Vec<String> {
        (0..len).map(|_| self.word(rng)).collect()
    }

    /// One (text, label) example of the given task.
    pub fn example(&self, task: &str, rng: &mut Rng) -> (String, i32) {
        match task {
            "sst2" => {
                // Sentiment = majority polarity of injected opinion words.
                let mut ws = self.sentence(rng, 4);
                let label = rng.below(2) as i32;
                let (pool, other) = if label == 1 {
                    (&self.positive, &self.negative)
                } else {
                    (&self.negative, &self.positive)
                };
                for _ in 0..2 {
                    ws.push(pool[rng.below(pool.len())].to_string());
                }
                if rng.chance(0.5) {
                    ws.push(other[rng.below(other.len())].to_string());
                }
                rng.shuffle(&mut ws);
                (ws.join(" "), label)
            }
            "cola" => {
                // "Grammar": a sentence is acceptable iff its brackets
                // balance and no word repeats adjacently.
                let mut ws = self.sentence(rng, 5);
                let label = rng.below(2) as i32;
                if label == 1 {
                    ws.insert(1, "(".into());
                    ws.insert(4, ")".into());
                } else if rng.chance(0.5) {
                    ws.insert(1, ")".into());
                    ws.insert(3, "(".into());
                } else {
                    let w = ws[2].clone();
                    ws.insert(3, w);
                }
                (ws.join(" "), label)
            }
            "mnli" => {
                // premise ; hypothesis → entail / neutral / contradict.
                let prem = self.sentence(rng, 5);
                let label = rng.below(3) as i32;
                let hyp: Vec<String> = match label {
                    0 => prem[1..4].to_vec(), // entail: sub-span
                    1 => self.sentence(rng, 3), // neutral: unrelated
                    _ => {
                        let mut h = prem[1..4].to_vec();
                        h.insert(0, "not".into()); // contradict
                        h
                    }
                };
                (format!("{} ; {}", prem.join(" "), hyp.join(" ")), label)
            }
            "qqp" | "mrpc" => {
                // Pair equivalence: duplicate = shuffled copy (qqp) or
                // word-dropped copy (mrpc).
                let s1 = self.sentence(rng, 5);
                let label = rng.below(2) as i32;
                let s2: Vec<String> = if label == 1 {
                    let mut c = s1.clone();
                    if task == "qqp" {
                        rng.shuffle(&mut c);
                    } else {
                        c.remove(rng.below(c.len()));
                    }
                    c
                } else {
                    self.sentence(rng, 5)
                };
                (format!("{} ; {}", s1.join(" "), s2.join(" ")), label)
            }
            "qnli" => {
                // question about a word; sentence answers iff it contains it.
                let target = self.word(rng);
                let label = rng.below(2) as i32;
                let mut sent = self.sentence(rng, 5);
                if label == 1 {
                    let idx = rng.below(sent.len());
                    sent[idx] = target.clone();
                }
                (format!("where {} ; {}", target, sent.join(" ")), label)
            }
            "rte" => {
                let prem = self.sentence(rng, 5);
                let label = rng.below(2) as i32;
                let hyp: Vec<String> = if label == 1 {
                    prem[..3].to_vec()
                } else {
                    self.sentence(rng, 3)
                };
                (format!("{} ; {}", prem.join(" "), hyp.join(" ")), label)
            }
            "stsb" => {
                // Ordinal similarity 0–3 = shared-word count bucket.
                let s1 = self.sentence(rng, 4);
                let level = rng.below(4);
                let mut s2 = self.sentence(rng, 4);
                for k in 0..level {
                    s2[k] = s1[k].clone();
                }
                if level == 3 {
                    s2[3] = s1[3].clone();
                }
                (format!("{} ; {}", s1.join(" "), s2.join(" ")), level as i32)
            }
            _ => panic!("unknown task {task}"),
        }
    }

    /// A deterministic batch; `split` 0 = train stream, 1 = test stream.
    pub fn batch(&self, task: &str, b: usize, s: usize, step: u64, split: u64) -> ClsBatch {
        let task_salt: u64 = task.bytes().map(|x| x as u64).sum();
        let mut rng =
            Rng::new(self.seed ^ task_salt.wrapping_mul(0x1009) ^ (split << 40)).fork(step);
        let mut docs = vec![];
        let mut labels = vec![];
        for _ in 0..b {
            let (text, label) = self.example(task, &mut rng);
            docs.push(encode(&text));
            labels.push(label);
        }
        ClsBatch::pack(&docs, &labels, b, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_labels() {
        let g = GlueGen::new(1);
        let mut rng = Rng::new(0);
        for task in TASKS {
            for _ in 0..50 {
                let (text, label) = g.example(task, &mut rng);
                assert!(!text.is_empty());
                assert!((label as usize) < n_classes(task), "{task}: {label}");
            }
        }
    }

    #[test]
    fn labels_are_learnable_signal() {
        // Sanity: examples of different labels differ systematically —
        // the label is recoverable from the text for a rule-based check
        // on sst2 (polarity majority).
        let g = GlueGen::new(2);
        let mut rng = Rng::new(1);
        let mut correct = 0;
        let n = 200;
        for _ in 0..n {
            let (text, label) = g.example("sst2", &mut rng);
            let words: Vec<&str> = text.split(' ').collect();
            let pos = words.iter().filter(|w| g.positive.contains(w)).count();
            let neg = words.iter().filter(|w| g.negative.contains(w)).count();
            let guess = if pos > neg { 1 } else { 0 };
            if guess == label {
                correct += 1;
            }
        }
        assert!(correct as f64 / n as f64 > 0.95);
    }

    #[test]
    fn train_test_streams_differ() {
        let g = GlueGen::new(3);
        let a = g.batch("mnli", 8, 32, 0, 0);
        let b = g.batch("mnli", 8, 32, 0, 1);
        assert_ne!(a.tokens, b.tokens);
        let a2 = g.batch("mnli", 8, 32, 0, 0);
        assert_eq!(a.tokens, a2.tokens);
    }

    #[test]
    fn metrics_map() {
        assert_eq!(metric_of("cola"), "matthews");
        assert_eq!(metric_of("stsb"), "pearson");
        assert_eq!(metric_of("mnli"), "accuracy");
        assert_eq!(n_classes("mnli"), 3);
    }
}
