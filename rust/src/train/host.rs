//! Host-native differentiable PEFT training — the first path in the
//! repo that **trains** without a PJRT artifact.
//!
//! The PJRT trainers in [`crate::train::trainer`] drive compiled
//! `*_train` artifacts and silently skip on a bare checkout; this
//! module closes that gap by wiring the `TransformOp` gradient surface
//! ([`crate::peft::op::TransformOp::grad_params_into`]) into a complete
//! optimizer loop over the same blocked-parallel infrastructure the
//! serving layer uses:
//!
//! ```text
//!  probe(step)        deterministic per-step batch (seed ⊕ step)
//!    │
//!    ▼
//!  MergePlan::execute_activations      y  = T_θ(W)·x   (merge-free)
//!  MergePlan::execute_activations      y* = T_θ*(W)·x  (hidden teacher)
//!    │
//!    ▼
//!  objective            least-squares ½‖y − y*‖²/N, or logistic over
//!    │                  readout scores with teacher-sign labels
//!    ▼
//!  MergePlan::execute_grad_activations  ∂L/∂θ  (blocked over items,
//!    │                                   bit-identical ∀ thread counts)
//!    ▼
//!  Adam → re-normalize reflection vectors (ETHER/ETHER+, §3.2)
//! ```
//!
//! Targets come from a **hidden same-family teacher adapter** (the
//! student's init plus a small perturbation), so every objective is
//! realizable and the paper's §4.3 LR-robustness story — ETHER/ETHER+
//! stable across orders of magnitude of learning rate while
//! unconstrained methods degrade — reproduces on a bare checkout
//! (`cargo run --example lr_robustness -- --host`).
//!
//! ```
//! use ether::peft::apply::ModelDims;
//! use ether::train::host::{HostTrainCfg, HostTrainer, Objective};
//! use ether::train::Schedule;
//!
//! // A tiny synthetic model: targets come from a hidden same-family
//! // "teacher" adapter, so the objective is realizable.
//! let cfg = HostTrainCfg {
//!     dims: ModelDims { d_model: 16, d_ff: 32, n_layers: 1 },
//!     method: "ether_n4".into(),
//!     objective: Objective::LeastSquares,
//!     ..HostTrainCfg::default()
//! };
//! let mut tr = HostTrainer::new(cfg).unwrap();
//! tr.train_step(1e-2).unwrap();
//! tr.run(9, Schedule::Const(1e-2)).unwrap();
//! assert_eq!(tr.losses.len(), 10);
//! assert!(tr.losses.iter().all(|l| l.is_finite()));
//! // Per-step telemetry records the paper's bounded-transform axis.
//! let last = tr.telemetry.last().unwrap();
//! assert!(last.param_norm > 0.0 && last.distance.is_finite());
//! ```

use std::path::Path;

use anyhow::{ensure, Result};

use crate::peft::apply::{base_layout_for, peft_layout_for, AdapterRef, MergePlan, ModelDims};
use crate::peft::flat::Layout;
use crate::peft::transforms as tf;
use crate::peft::{adapted_matrices, metrics, registry, MethodKind, MethodSpec};
use crate::train::{checkpoint, Schedule};
use crate::util::json::Value;
use crate::util::rng::Rng;

const BETA1: f64 = 0.9;
const BETA2: f64 = 0.999;
const ADAM_EPS: f64 = 1e-8;

/// Training objective over the concatenated activation outputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// `½·‖y − y*‖² / N` — the synthetic least-squares probe.
    LeastSquares,
    /// Binary logistic regression per (item, column): scores are fixed
    /// random readouts of the activation outputs, labels are the
    /// teacher score's sign.
    Logistic,
}

impl Objective {
    pub fn parse(s: &str) -> Result<Objective> {
        match s {
            "lsq" | "least-squares" => Ok(Objective::LeastSquares),
            "logistic" => Ok(Objective::Logistic),
            other => anyhow::bail!("unknown objective {other:?} (expected lsq | logistic)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::LeastSquares => "lsq",
            Objective::Logistic => "logistic",
        }
    }
}

/// Configuration of one host training run.
#[derive(Clone, Debug)]
pub struct HostTrainCfg {
    pub dims: ModelDims,
    /// Canonical method name (`"ether_n4"`, `"lora_r8"`, …); must be a
    /// member of the differentiable family.
    pub method: String,
    pub objective: Objective,
    /// Probe columns per step (the batch dimension `m`).
    pub batch_cols: usize,
    /// Seeds the base weights, the init, the teacher and every
    /// per-step probe — two runs with the same cfg are bit-identical.
    pub seed: u64,
    /// Scale of the random PEFT init (`full` instead starts at the
    /// frozen base weights).
    pub init_scale: f32,
    /// Scale of the teacher's perturbation away from the init.
    pub teacher_scale: f32,
    /// Record the (non-free) transformation distance each step.
    pub telemetry: bool,
}

impl Default for HostTrainCfg {
    fn default() -> HostTrainCfg {
        HostTrainCfg {
            dims: ModelDims { d_model: 32, d_ff: 64, n_layers: 2 },
            method: "etherplus_n4".into(),
            objective: Objective::LeastSquares,
            batch_cols: 4,
            seed: 17,
            init_scale: 0.1,
            teacher_scale: 0.3,
            telemetry: true,
        }
    }
}

/// Per-step telemetry row — the LR-robustness sweep's raw material.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub step: u64,
    pub lr: f32,
    pub loss: f32,
    pub grad_norm: f32,
    pub param_norm: f32,
    /// Paper Fig. 4 transformation distance (NaN when
    /// [`HostTrainCfg::telemetry`] is off — it materializes per-item
    /// transforms and is not free).
    pub distance: f32,
}

/// Host-native PEFT trainer: synthetic least-squares / logistic probes
/// over [`crate::tensor::Mat`]-shaped activations, Adam, the shared
/// [`Schedule`], and per-step param-norm / transform-distance
/// telemetry. See the module docs for the pipeline walkthrough.
pub struct HostTrainer {
    pub cfg: HostTrainCfg,
    pub spec: MethodSpec,
    pub base: Vec<f32>,
    pub base_layout: Layout,
    pub plan: MergePlan,
    pub peft_layout: Layout,
    /// Flat PEFT parameters (the trained state).
    pub peft: Vec<f32>,
    /// Adam first/second moments.
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
    pub losses: Vec<f32>,
    pub telemetry: Vec<StepStats>,
    teacher_peft: Vec<f32>,
    readout: Vec<f32>,
}

fn l2(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        (1.0 + x.exp()).ln()
    }
}

fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

impl HostTrainer {
    pub fn new(cfg: HostTrainCfg) -> Result<HostTrainer> {
        let spec = MethodSpec::parse(&cfg.method)?;
        let op = registry::op_for(spec.kind);
        ensure!(
            op.supports_grad(),
            "{} does not support host-native training (no gradient surface)",
            op.token()
        );
        let base_layout = base_layout_for(cfg.dims);
        let plan = MergePlan::new(cfg.dims, &base_layout)?;
        let mut rng = Rng::new(cfg.seed);
        let base = rng.normal_vec(base_layout.total, 0.05);
        let peft_layout = peft_layout_for(cfg.dims, &spec);
        let peft = Self::init_peft(&cfg, &spec, &base, &base_layout, &peft_layout, &mut rng)?;
        // The hidden teacher: the student's init plus a bounded
        // perturbation — realizable within the same family, and close
        // enough that the low-LR end of a robustness sweep converges
        // within a few hundred steps.
        let mut teacher_peft = peft.clone();
        for p in teacher_peft.iter_mut() {
            *p += cfg.teacher_scale * rng.normal();
        }
        let readout = rng.normal_vec(plan.activations_out_len(1), 1.0);
        let k = peft.len();
        Ok(HostTrainer {
            cfg,
            spec,
            base,
            base_layout,
            plan,
            peft_layout,
            peft,
            m: vec![0.0; k],
            v: vec![0.0; k],
            step: 0,
            losses: vec![],
            telemetry: vec![],
            teacher_peft,
            readout,
        })
    }

    /// Fresh PEFT init: `full` starts at the frozen base weights (its
    /// parameters *are* the replacement matrices); everything else
    /// starts at a small random point.
    fn init_peft(
        cfg: &HostTrainCfg,
        spec: &MethodSpec,
        base: &[f32],
        base_layout: &Layout,
        peft_layout: &Layout,
        rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        if spec.kind == MethodKind::Full {
            let mut peft = vec![0.0f32; peft_layout.total];
            for (name, _, _) in adapted_matrices(cfg.dims.d_model, cfg.dims.d_ff) {
                for l in 0..cfg.dims.n_layers {
                    let src = base_layout.view_layer(base, name, l)?;
                    peft_layout
                        .view_layer_mut(&mut peft, &format!("{name}.w"), l)?
                        .copy_from_slice(src);
                }
            }
            Ok(peft)
        } else {
            Ok(rng.normal_vec(peft_layout.total, cfg.init_scale))
        }
    }

    /// Deterministic per-step probe batch: the training "data" is keyed
    /// by (seed, step), so a resumed run replays exactly the same
    /// batches — the bit-identical-resume guarantee rests on this.
    pub fn probe(&self, step: u64) -> Vec<f32> {
        let key = self.cfg.seed ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0x5DEE_CE66);
        let mut rng = Rng::new(key);
        rng.normal_vec(self.plan.max_item_cols() * self.cfg.batch_cols, 1.0)
    }

    fn forward(&self, peft: &[f32], x: &[f32], threads: Option<usize>) -> Result<Vec<f32>> {
        let m = self.cfg.batch_cols;
        let mut y = vec![0.0f32; self.plan.activations_out_len(m)];
        self.plan.execute_activations(
            AdapterRef { spec: &self.spec, peft, layout: &self.peft_layout },
            &self.base,
            x,
            m,
            &mut y,
            threads,
        )?;
        Ok(y)
    }

    /// Loss and `∂L/∂y` for student outputs `y` against teacher
    /// outputs `t`, in f64.
    fn loss_and_upstream(&self, y: &[f32], t: &[f32]) -> (f64, Vec<f32>) {
        let m = self.cfg.batch_cols;
        match self.cfg.objective {
            Objective::LeastSquares => {
                let n = y.len() as f64;
                let mut loss = 0.0f64;
                let mut up = vec![0.0f32; y.len()];
                for ((u, &yv), &tv) in up.iter_mut().zip(y).zip(t) {
                    let d = yv as f64 - tv as f64;
                    loss += d * d;
                    *u = (d / n) as f32;
                }
                (loss / (2.0 * n), up)
            }
            Objective::Logistic => {
                let mut up = vec![0.0f32; y.len()];
                let mut loss = 0.0f64;
                let count = (self.plan.items.len() * m) as f64;
                let mut pos = 0usize; // item region start in y
                let mut roff = 0usize; // item region start in readout
                for it in &self.plan.items {
                    for c in 0..m {
                        let (mut s, mut st) = (0.0f64, 0.0f64);
                        for row in 0..it.rows {
                            let r = self.readout[roff + row] as f64;
                            s += r * y[pos + row * m + c] as f64;
                            st += r * t[pos + row * m + c] as f64;
                        }
                        let label = if st >= 0.0 { 1.0 } else { -1.0 };
                        let z = -label * s;
                        loss += softplus(z) / count;
                        let dls = -label * sigmoid(z) / count;
                        for row in 0..it.rows {
                            up[pos + row * m + c] += (dls * self.readout[roff + row] as f64) as f32;
                        }
                    }
                    pos += it.rows * m;
                    roff += it.rows;
                }
                (loss, up)
            }
        }
    }

    /// Loss and flat parameter gradient at the current parameters on
    /// probe batch `x`. `threads: None` uses the ambient pool,
    /// `Some(1)` pins the serial oracle — bit-identical either way
    /// (the property `rust/tests/grad_props.rs` and the `train_step`
    /// bench assert).
    pub fn loss_and_grad(&self, x: &[f32], threads: Option<usize>) -> Result<(f64, Vec<f32>)> {
        let m = self.cfg.batch_cols;
        let y = self.forward(&self.peft, x, threads)?;
        let t = self.forward(&self.teacher_peft, x, threads)?;
        let (loss, up) = self.loss_and_upstream(&y, &t);
        let mut grad = vec![0.0f32; self.peft_layout.total];
        self.plan.execute_grad_activations(
            AdapterRef { spec: &self.spec, peft: &self.peft, layout: &self.peft_layout },
            &self.base,
            x,
            m,
            &up,
            &mut grad,
            threads,
        )?;
        Ok((loss, grad))
    }

    /// Loss on a held-out probe batch (a step key no training step
    /// ever uses).
    pub fn eval_loss(&self) -> Result<f64> {
        let x = self.probe(u64::MAX);
        let y = self.forward(&self.peft, &x, None)?;
        let t = self.forward(&self.teacher_peft, &x, None)?;
        Ok(self.loss_and_upstream(&y, &t).0)
    }

    /// One Adam step at learning rate `lr` on the step-keyed probe
    /// batch; returns the (pre-update) training loss.
    pub fn train_step(&mut self, lr: f32) -> Result<f32> {
        let x = self.probe(self.step);
        let (loss, grad) = self.loss_and_grad(&x, None)?;
        self.step += 1;
        let bc1 = 1.0 - BETA1.powi(self.step as i32);
        let bc2 = 1.0 - BETA2.powi(self.step as i32);
        for k in 0..self.peft.len() {
            let g = grad[k] as f64;
            let m = BETA1 * self.m[k] as f64 + (1.0 - BETA1) * g;
            let v = BETA2 * self.v[k] as f64 + (1.0 - BETA2) * g * g;
            self.m[k] = m as f32;
            self.v[k] = v as f32;
            let update = lr as f64 * (m / bc1) / ((v / bc2).sqrt() + ADAM_EPS);
            self.peft[k] = (self.peft[k] as f64 - update) as f32;
        }
        self.renormalize_reflections()?;
        let distance =
            if self.cfg.telemetry { self.transform_distance()? as f32 } else { f32::NAN };
        self.telemetry.push(StepStats {
            step: self.step,
            lr,
            loss: loss as f32,
            grad_norm: l2(&grad),
            param_norm: l2(&self.peft),
            distance,
        });
        self.losses.push(loss as f32);
        Ok(loss as f32)
    }

    /// Run `steps` optimizer steps under `sched` (indexed by the
    /// trainer's own step counter, so a resumed run continues the
    /// schedule), stopping early with a warning on a non-finite loss —
    /// divergence is *data* for the LR-robustness sweep, not a crash.
    pub fn run(&mut self, steps: u64, sched: Schedule) -> Result<()> {
        for _ in 0..steps {
            let lr = sched.lr(self.step);
            let loss = self.train_step(lr)?;
            if !loss.is_finite() {
                log::warn!(
                    "{}: non-finite loss at step {} (lr={lr:.1e}) — divergence",
                    self.cfg.method,
                    self.step
                );
                break;
            }
        }
        Ok(())
    }

    /// Post-step projection: re-normalize every reflection vector
    /// block to unit norm, as the paper prescribes for ETHER training
    /// (§3.2/§3.3). Function values are unchanged — the kernels
    /// normalize internally — but the projection keeps Adam's geometry
    /// well-conditioned and makes "unit-norm reflection vectors" a
    /// checkable invariant (`rust/tests/train_host.rs`). A no-op for
    /// methods whose op declares no reflection fields
    /// ([`crate::peft::op::TransformOp::unit_norm_fields`] — the op,
    /// not a kind match
    /// here, decides; `dispatch-discipline` keeps it that way).
    fn renormalize_reflections(&mut self) -> Result<()> {
        let fields = registry::op_for(self.spec.kind).unit_norm_fields(&self.spec);
        if fields.is_empty() {
            return Ok(());
        }
        let dims = self.cfg.dims;
        for (name, _, _) in adapted_matrices(dims.d_model, dims.d_ff) {
            for field in fields {
                let key = format!("{name}.{field}");
                for l in 0..dims.n_layers {
                    let slice = self.peft_layout.view_layer_mut(&mut self.peft, &key, l)?;
                    let normed = tf::normalize_blocks(slice, self.spec.n_blocks);
                    slice.copy_from_slice(&normed);
                }
            }
        }
        Ok(())
    }

    /// Aggregate transformation distance of the current adapter (paper
    /// Fig. 4) — the bounded-transform telemetry axis.
    pub fn transform_distance(&self) -> Result<f64> {
        metrics::transformation_distance(self.cfg.dims, &self.spec, &self.peft, &self.peft_layout)
    }

    pub fn param_norm(&self) -> f32 {
        l2(&self.peft)
    }

    /// Persist the full optimizer state (peft + Adam moments + step)
    /// for a bit-identical resume.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        checkpoint::save_state(
            path,
            &checkpoint::TrainState {
                peft: self.peft.clone(),
                m: self.m.clone(),
                v: self.v.clone(),
                step: self.step,
            },
            vec![
                ("method", Value::s(self.cfg.method.clone())),
                ("objective", Value::s(self.cfg.objective.name())),
            ],
        )
    }

    /// Restore state saved by [`HostTrainer::save_checkpoint`] into a
    /// freshly constructed trainer with the same cfg; continuing the
    /// run then replays bit-identically to the uninterrupted one.
    pub fn resume_from(&mut self, path: &Path) -> Result<()> {
        let (st, meta) = checkpoint::load_state(path)?;
        let method = meta.at("method")?.as_str()?;
        ensure!(
            method == self.cfg.method,
            "checkpoint is for {method:?}, this trainer runs {:?}",
            self.cfg.method
        );
        let objective = meta.at("objective")?.as_str()?;
        ensure!(
            objective == self.cfg.objective.name(),
            "checkpoint was trained on the {objective:?} objective, this trainer runs {:?} — \
             Adam moments are not transferable across losses",
            self.cfg.objective.name()
        );
        ensure!(
            st.peft.len() == self.peft.len()
                && st.m.len() == self.m.len()
                && st.v.len() == self.v.len(),
            "checkpoint state sizes do not match this trainer"
        );
        self.peft = st.peft;
        self.m = st.m;
        self.v = st.v;
        self.step = st.step;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(method: &str) -> HostTrainCfg {
        HostTrainCfg {
            dims: ModelDims { d_model: 16, d_ff: 32, n_layers: 1 },
            method: method.into(),
            batch_cols: 2,
            ..HostTrainCfg::default()
        }
    }

    #[test]
    fn objective_names_roundtrip() {
        for o in [Objective::LeastSquares, Objective::Logistic] {
            assert_eq!(Objective::parse(o.name()).unwrap(), o);
        }
        assert!(Objective::parse("mse").is_err());
    }

    #[test]
    fn trainer_rejects_non_differentiable_methods() {
        for method in ["none", "vera_r4"] {
            let err = HostTrainer::new(tiny_cfg(method)).unwrap_err();
            assert!(format!("{err:#}").contains("grad"), "{method}: {err:#}");
        }
    }

    #[test]
    fn full_init_starts_at_the_frozen_base() {
        let tr = HostTrainer::new(tiny_cfg("full")).unwrap();
        // Zero transformation distance at init: the replacement weights
        // equal the base, so the first loss is exactly the teacher gap.
        let w0 = tr.peft_layout.view_layer(&tr.peft, "wq.w", 0).unwrap();
        let b0 = tr.base_layout.view_layer(&tr.base, "wq", 0).unwrap();
        assert_eq!(w0, b0);
    }

    #[test]
    fn losses_are_deterministic_across_runs() {
        let mut a = HostTrainer::new(tiny_cfg("ether_n4")).unwrap();
        let mut b = HostTrainer::new(tiny_cfg("ether_n4")).unwrap();
        a.run(3, Schedule::Const(1e-2)).unwrap();
        b.run(3, Schedule::Const(1e-2)).unwrap();
        assert_eq!(
            a.peft.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.peft.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "same cfg must train bit-identically"
        );
        assert_eq!(a.losses, b.losses);
    }

    #[test]
    fn logistic_objective_trains_finite() {
        let mut cfg = tiny_cfg("lora_r4");
        cfg.objective = Objective::Logistic;
        let mut tr = HostTrainer::new(cfg).unwrap();
        tr.run(5, Schedule::Const(1e-2)).unwrap();
        assert_eq!(tr.losses.len(), 5);
        assert!(tr.losses.iter().all(|l| l.is_finite() && *l >= 0.0));
    }
}
