//! Checkpoints: flat f32 weights as raw little-endian + JSON sidecar.
//!
//! Besides the plain flat-vector form ([`save`]/[`load`]), trainers
//! persist their full optimizer state as a [`TrainState`]
//! ([`save_state`]/[`load_state`]): the PEFT parameters plus Adam's
//! first/second moments and the step counter, packed into one raw file
//! with the section lengths recorded in the JSON sidecar — a resumed
//! run continues **bit-identically** (locked in by
//! `rust/tests/train_host.rs`). Corrupted files (truncated payload,
//! mangled sidecar, wrong kind) load as errors, never panics.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::json::Value;

/// Save a flat parameter vector with metadata.
pub fn save(path: &Path, vec: &[f32], meta: Value) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let bytes: Vec<u8> = vec.iter().flat_map(|f| f.to_le_bytes()).collect();
    std::fs::write(path, bytes)?;
    std::fs::write(path.with_extension("json"), meta.dump())?;
    Ok(())
}

/// Load a flat parameter vector and its metadata.
pub fn load(path: &Path) -> Result<(Vec<f32>, Value)> {
    let bytes = std::fs::read(path).with_context(|| format!("reading checkpoint {path:?}"))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "checkpoint not f32-aligned");
    let vec = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let meta_path = path.with_extension("json");
    let meta = if meta_path.exists() {
        crate::util::json::parse(&std::fs::read_to_string(meta_path)?)?
    } else {
        Value::Null
    };
    Ok((vec, meta))
}

/// Full optimizer state of a training run: PEFT parameters, Adam
/// moments and the step counter — everything needed for a
/// bit-identical resume.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    pub peft: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
}

/// Save a [`TrainState`] (sections concatenated, lengths + step in the
/// sidecar). `extra` lands in the sidecar alongside the state fields —
/// trainers record their method/objective so a resume can validate.
pub fn save_state(path: &Path, st: &TrainState, extra: Vec<(&str, Value)>) -> Result<()> {
    let mut meta = vec![
        ("kind", Value::s("train_state")),
        ("peft_len", Value::num(st.peft.len() as f64)),
        ("m_len", Value::num(st.m.len() as f64)),
        ("v_len", Value::num(st.v.len() as f64)),
        ("step", Value::num(st.step as f64)),
    ];
    meta.extend(extra);
    let mut cat = Vec::with_capacity(st.peft.len() + st.m.len() + st.v.len());
    cat.extend_from_slice(&st.peft);
    cat.extend_from_slice(&st.m);
    cat.extend_from_slice(&st.v);
    save(path, &cat, Value::obj(meta))
}

/// Load a [`TrainState`] and its full sidecar. Every failure mode —
/// missing file, truncated payload, mangled JSON, wrong kind,
/// inconsistent section lengths — is an error, never a panic.
pub fn load_state(path: &Path) -> Result<(TrainState, Value)> {
    let (cat, meta) = load(path)?;
    let kind = meta
        .at("kind")
        .and_then(Value::as_str)
        .with_context(|| format!("checkpoint {path:?} has no train-state sidecar"))?;
    ensure!(kind == "train_state", "checkpoint {path:?} is not a train state (kind {kind:?})");
    let peft_len = meta.at("peft_len")?.as_usize()?;
    let m_len = meta.at("m_len")?.as_usize()?;
    let v_len = meta.at("v_len")?.as_usize()?;
    let step = meta.at("step")?.as_usize()? as u64;
    ensure!(
        peft_len + m_len + v_len == cat.len(),
        "checkpoint {path:?}: sections {peft_len}+{m_len}+{v_len} != payload {}",
        cat.len()
    );
    let mut cat = cat;
    let v = cat.split_off(peft_len + m_len);
    let m = cat.split_off(peft_len);
    Ok((TrainState { peft: cat, m, v, step }, meta))
}

/// Conventional checkpoint path: `checkpoints/<name>.f32`.
pub fn path_for(name: &str) -> std::path::PathBuf {
    let root = crate::artifacts_dir()
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| ".".into());
    root.join("checkpoints").join(format!("{name}.f32"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("ether_ckpt_test");
        let path = dir.join("x.f32");
        let vec = vec![1.0f32, -2.5, 3.25];
        let meta = Value::obj(vec![("steps", Value::num(42.0))]);
        save(&path, &vec, meta).unwrap();
        let (back, m) = load(&path).unwrap();
        assert_eq!(back, vec);
        assert_eq!(m.at("steps").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn missing_file_errors() {
        assert!(load(Path::new("/nonexistent/ckpt.f32")).is_err());
    }

    #[test]
    fn train_state_roundtrip_is_bit_identical() {
        let dir = std::env::temp_dir().join("ether_ckpt_state_test");
        let path = dir.join("state.f32");
        let st = TrainState {
            peft: vec![1.0, -2.5, f32::MIN_POSITIVE, 3.25e-7],
            m: vec![0.125, -0.25],
            v: vec![9.5, 0.0, -0.0],
            step: 17,
        };
        save_state(&path, &st, vec![("method", Value::s("ether_n4"))]).unwrap();
        let (back, meta) = load_state(&path).unwrap();
        assert_eq!(back.step, 17);
        assert_eq!(meta.at("method").unwrap().as_str().unwrap(), "ether_n4");
        // Bit-identical, not just approximately equal.
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.peft), bits(&st.peft));
        assert_eq!(bits(&back.m), bits(&st.m));
        assert_eq!(bits(&back.v), bits(&st.v));
    }

    #[test]
    fn corrupted_files_error_instead_of_panicking() {
        let dir = std::env::temp_dir().join("ether_ckpt_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        // Truncated payload (not f32-aligned).
        let odd = dir.join("odd.f32");
        std::fs::write(&odd, [1u8, 2, 3]).unwrap();
        assert!(load(&odd).is_err());
        assert!(load_state(&odd).is_err());
        // Mangled JSON sidecar.
        let bad_meta = dir.join("badmeta.f32");
        std::fs::write(&bad_meta, 1.0f32.to_le_bytes()).unwrap();
        std::fs::write(bad_meta.with_extension("json"), "{not json!").unwrap();
        assert!(load(&bad_meta).is_err());
        assert!(load_state(&bad_meta).is_err());
        // Valid payload but a sidecar of the wrong kind.
        let wrong = dir.join("wrong.f32");
        save(&wrong, &[1.0, 2.0], Value::obj(vec![("steps", Value::num(1.0))])).unwrap();
        let err = load_state(&wrong).unwrap_err();
        assert!(format!("{err:#}").contains("train-state"), "{err:#}");
        // Sections that do not add up to the payload.
        let short = dir.join("short.f32");
        let st = TrainState { peft: vec![1.0, 2.0], m: vec![3.0], v: vec![4.0], step: 1 };
        save_state(&short, &st, vec![]).unwrap();
        std::fs::write(&short, 1.0f32.to_le_bytes()).unwrap(); // truncate payload
        assert!(load_state(&short).is_err());
    }
}
