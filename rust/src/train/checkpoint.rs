//! Checkpoints: flat f32 weights as raw little-endian + JSON sidecar.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Value;

/// Save a flat parameter vector with metadata.
pub fn save(path: &Path, vec: &[f32], meta: Value) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let bytes: Vec<u8> = vec.iter().flat_map(|f| f.to_le_bytes()).collect();
    std::fs::write(path, bytes)?;
    std::fs::write(path.with_extension("json"), meta.dump())?;
    Ok(())
}

/// Load a flat parameter vector and its metadata.
pub fn load(path: &Path) -> Result<(Vec<f32>, Value)> {
    let bytes = std::fs::read(path).with_context(|| format!("reading checkpoint {path:?}"))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "checkpoint not f32-aligned");
    let vec = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let meta_path = path.with_extension("json");
    let meta = if meta_path.exists() {
        crate::util::json::parse(&std::fs::read_to_string(meta_path)?)?
    } else {
        Value::Null
    };
    Ok((vec, meta))
}

/// Conventional checkpoint path: `checkpoints/<name>.f32`.
pub fn path_for(name: &str) -> std::path::PathBuf {
    let root = crate::artifacts_dir()
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| ".".into());
    root.join("checkpoints").join(format!("{name}.f32"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("ether_ckpt_test");
        let path = dir.join("x.f32");
        let vec = vec![1.0f32, -2.5, 3.25];
        let meta = Value::obj(vec![("steps", Value::num(42.0))]);
        save(&path, &vec, meta).unwrap();
        let (back, m) = load(&path).unwrap();
        assert_eq!(back, vec);
        assert_eq!(m.at("steps").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn missing_file_errors() {
        assert!(load(Path::new("/nonexistent/ckpt.f32")).is_err());
    }
}
