//! Training loops over the AOT artifacts.
//!
//! [`LmTrainer`] drives `*_train` / `*_pretrain` artifacts; [`ClsTrainer`]
//! drives `cls_*_train`. Both keep the large frozen base weights
//! **device-resident** (uploaded once, reused via `execute_b`) so each
//! step only moves the small PEFT state and the batch — the L3 hot-path
//! optimization measured in EXPERIMENTS.md §Perf.
//!
//! These trainers need `artifacts/manifest.json` and real PJRT
//! bindings; on a bare checkout use the artifact-free
//! [`crate::train::host::HostTrainer`], which trains through the
//! `TransformOp` gradient surface instead.

use anyhow::Result;

use crate::data::{ClsBatch, LmBatch};
use crate::runtime::engine::{PjrtEngine, PjrtExec};
use crate::runtime::HostTensor;
use crate::train::Schedule;

/// Adapter/PEFT training over an `lm_<cfg>_<method>_train` artifact.
pub struct LmTrainer<'e> {
    pub engine: &'e PjrtEngine,
    pub cfg: String,
    pub method: String,
    /// None for eval-only instances (e.g. scoring the un-tuned base).
    exec: Option<std::sync::Arc<PjrtExec>>,
    base_buf: Option<xla::PjRtBuffer>,
    base_host: Vec<f32>,
    pub peft: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
    pub losses: Vec<f32>,
}

impl<'e> LmTrainer<'e> {
    /// Create a trainer from the init dumps ("fresh adapter on the given
    /// base"). `base` defaults to the init dump; pass a pretrained
    /// checkpoint for the real experiments.
    pub fn new(
        engine: &'e PjrtEngine,
        cfg: &str,
        method: &str,
        base: Option<Vec<f32>>,
    ) -> Result<LmTrainer<'e>> {
        let exec = engine.load(&format!("lm_{cfg}_{method}_train"))?;
        let base_host = match base {
            Some(b) => b,
            None => engine.manifest.load_init(&format!("{cfg}_base"))?,
        };
        let base_buf = engine.upload(&HostTensor::vec_f32(base_host.clone()))?;
        let peft = engine.manifest.load_init(&format!("{cfg}_{method}_peft"))?;
        let k = peft.len();
        Ok(LmTrainer {
            engine,
            cfg: cfg.to_string(),
            method: method.to_string(),
            exec: Some(exec),
            base_buf: Some(base_buf),
            base_host,
            peft,
            m: vec![0.0; k],
            v: vec![0.0; k],
            step: 0,
            losses: vec![],
        })
    }

    /// Eval-only instance over existing (base, peft) — used to score the
    /// un-tuned baseline (`method = "none"`, `peft = [0.0]`) and loaded
    /// checkpoints without requiring a train artifact.
    pub fn eval_only(
        engine: &'e PjrtEngine,
        cfg: &str,
        method: &str,
        base: Vec<f32>,
        peft: Vec<f32>,
    ) -> Result<LmTrainer<'e>> {
        let k = peft.len();
        Ok(LmTrainer {
            engine,
            cfg: cfg.to_string(),
            method: method.to_string(),
            exec: None,
            base_buf: None,
            base_host: base,
            peft,
            m: vec![0.0; k],
            v: vec![0.0; k],
            step: 0,
            losses: vec![],
        })
    }

    /// Replace the adapter state (e.g. to resume or to seed a refit).
    /// The vector is zero-extended / truncated to the expected size —
    /// used by OFT magnitude-refit, whose layout extends plain OFT's.
    pub fn seed_peft(&mut self, mut peft: Vec<f32>) {
        peft.resize(self.peft.len(), 0.0);
        self.peft = peft;
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
    }

    /// One optimizer step; returns the loss.
    pub fn step(&mut self, batch: &LmBatch, lr: f32) -> Result<f32> {
        let exec = self
            .exec
            .clone()
            .ok_or_else(|| anyhow::anyhow!("eval-only trainer cannot step"))?;
        let base_buf = self.base_buf.as_ref().unwrap();
        self.step += 1;
        let (tok, tgt, mask) = batch.to_tensors();
        let small = [
            HostTensor::vec_f32(self.peft.clone()),
            HostTensor::vec_f32(self.m.clone()),
            HostTensor::vec_f32(self.v.clone()),
            tok,
            tgt,
            mask,
            HostTensor::scalar_f32(lr),
            HostTensor::scalar_f32(self.step as f32),
        ];
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(small.len());
        for t in &small {
            bufs.push(self.engine.upload(t)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = vec![base_buf];
        args.extend(bufs.iter());
        let out = exec.run_buffers(&args)?;
        self.peft = out[0].f32s()?.to_vec();
        self.m = out[1].f32s()?.to_vec();
        self.v = out[2].f32s()?.to_vec();
        let loss = out[3].scalar()?;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Run `steps` optimizer steps with a schedule and a batch source.
    pub fn run<F: FnMut(u64) -> LmBatch>(
        &mut self,
        steps: u64,
        sched: Schedule,
        mut batch_fn: F,
    ) -> Result<()> {
        for i in 0..steps {
            let batch = batch_fn(self.step);
            let lr = sched.lr(i);
            let loss = self.step(&batch, lr)?;
            if !loss.is_finite() {
                log::warn!(
                    "{}/{}: non-finite loss at step {} (lr={lr:.1e}) — divergence",
                    self.cfg,
                    self.method,
                    self.step
                );
                break;
            }
        }
        Ok(())
    }

    /// Per-example NLL via the matching eval artifact.
    pub fn eval_nll(&self, batch: &LmBatch) -> Result<Vec<f32>> {
        let exec = self.engine.load(&format!("lm_{}_{}_eval", self.cfg, self.method))?;
        let (tok, tgt, mask) = batch.to_tensors();
        let out = exec.run(&[
            HostTensor::vec_f32(self.base_host.clone()),
            HostTensor::vec_f32(self.peft.clone()),
            tok,
            tgt,
            mask,
        ])?;
        Ok(out[0].f32s()?.to_vec())
    }

    /// Mean masked NLL over a batch (convergence metric).
    pub fn eval_loss(&self, batch: &LmBatch) -> Result<f32> {
        let nll = self.eval_nll(batch)?;
        let tokens = batch.mask_tokens().max(1.0);
        Ok(nll.iter().sum::<f32>() / tokens)
    }

    /// Greedy generation: decode `max_new` tokens for each prompt row.
    /// Prompts are padded to the artifact batch; rows beyond `prompts`
    /// are dummies.
    pub fn generate(&self, prompts: &[Vec<i32>], max_new: usize) -> Result<Vec<Vec<i32>>> {
        let c = self.engine.manifest.config(&self.cfg)?.clone();
        let exec = self.engine.load(&format!("lm_{}_{}_logits", self.cfg, self.method))?;
        let mut rows: Vec<Vec<i32>> = prompts.to_vec();
        anyhow::ensure!(rows.len() <= c.batch, "too many prompts for batch {}", c.batch);
        rows.resize(c.batch, vec![crate::data::BOS]);
        let mut done = vec![false; c.batch];
        let base = HostTensor::vec_f32(self.base_host.clone());
        let peft = HostTensor::vec_f32(self.peft.clone());
        for _ in 0..max_new {
            let mut tokens = vec![crate::data::PAD; c.batch * c.seq];
            let mut lengths = vec![1i32; c.batch];
            for (i, row) in rows.iter().enumerate() {
                // Sliding window if the row exceeds the context.
                let start = row.len().saturating_sub(c.seq);
                let window = &row[start..];
                tokens[i * c.seq..i * c.seq + window.len()].copy_from_slice(window);
                lengths[i] = window.len() as i32;
            }
            let out = exec.run(&[
                base.clone(),
                peft.clone(),
                HostTensor::mat_i32(c.batch, c.seq, tokens),
                HostTensor::vec_i32(lengths),
            ])?;
            let logits = out[0].f32s()?;
            let mut all_done = true;
            for i in 0..prompts.len() {
                if done[i] {
                    continue;
                }
                let row = &logits[i * c.vocab..(i + 1) * c.vocab];
                let next = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(t, _)| t as i32)
                    .unwrap_or(crate::data::EOS);
                if next == crate::data::EOS || next == crate::data::PAD {
                    done[i] = true;
                } else {
                    rows[i].push(next);
                    all_done = false;
                }
            }
            if all_done {
                break;
            }
        }
        Ok(rows[..prompts.len()]
            .iter()
            .zip(prompts)
            .map(|(row, p)| row[p.len()..].to_vec())
            .collect())
    }

    /// Merge the adapter into base weights via the HLO merge artifact.
    pub fn merged_base(&self) -> Result<Vec<f32>> {
        let exec = self.engine.load(&format!("lm_{}_{}_merge", self.cfg, self.method))?;
        let out = exec.run(&[
            HostTensor::vec_f32(self.base_host.clone()),
            HostTensor::vec_f32(self.peft.clone()),
        ])?;
        Ok(out[0].f32s()?.to_vec())
    }

    pub fn base(&self) -> &[f32] {
        &self.base_host
    }
}

/// Full-weight pretraining over `lm_<cfg>_pretrain`.
pub struct Pretrainer<'e> {
    pub engine: &'e PjrtEngine,
    pub cfg: String,
    exec: std::sync::Arc<PjrtExec>,
    pub base: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    pub step: u64,
    pub losses: Vec<f32>,
}

impl<'e> Pretrainer<'e> {
    pub fn new(engine: &'e PjrtEngine, cfg: &str) -> Result<Pretrainer<'e>> {
        let exec = engine.load(&format!("lm_{cfg}_pretrain"))?;
        let base = engine.manifest.load_init(&format!("{cfg}_base"))?;
        let n = base.len();
        Ok(Pretrainer {
            engine,
            cfg: cfg.to_string(),
            exec,
            base,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
            losses: vec![],
        })
    }

    pub fn step(&mut self, batch: &LmBatch, lr: f32) -> Result<f32> {
        self.step += 1;
        let (tok, tgt, mask) = batch.to_tensors();
        let out = self.exec.run(&[
            HostTensor::vec_f32(self.base.clone()),
            HostTensor::vec_f32(self.m.clone()),
            HostTensor::vec_f32(self.v.clone()),
            tok,
            tgt,
            mask,
            HostTensor::scalar_f32(lr),
            HostTensor::scalar_f32(self.step as f32),
        ])?;
        self.base = out[0].f32s()?.to_vec();
        self.m = out[1].f32s()?.to_vec();
        self.v = out[2].f32s()?.to_vec();
        let loss = out[3].scalar()?;
        self.losses.push(loss);
        Ok(loss)
    }
}

/// Classifier finetuning over `cls_<cfg>_<method>_train` (SynthGLUE).
pub struct ClsTrainer<'e> {
    pub engine: &'e PjrtEngine,
    pub cfg: String,
    pub method: String,
    exec: std::sync::Arc<PjrtExec>,
    base_buf: xla::PjRtBuffer,
    base_host: Vec<f32>,
    pub t: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    pub step: u64,
    pub losses: Vec<f32>,
}

impl<'e> ClsTrainer<'e> {
    pub fn new(
        engine: &'e PjrtEngine,
        cfg: &str,
        method: &str,
        base: Option<Vec<f32>>,
    ) -> Result<ClsTrainer<'e>> {
        let exec = engine.load(&format!("cls_{cfg}_{method}_train"))?;
        let base_host = match base {
            Some(b) => b,
            None => engine.manifest.load_init(&format!("{cfg}_base"))?,
        };
        let base_buf = engine.upload(&HostTensor::vec_f32(base_host.clone()))?;
        let t = engine.manifest.load_init(&format!("{cfg}_{method}_cls"))?;
        let k = t.len();
        Ok(ClsTrainer {
            engine,
            cfg: cfg.to_string(),
            method: method.to_string(),
            exec,
            base_buf,
            base_host,
            t,
            m: vec![0.0; k],
            v: vec![0.0; k],
            step: 0,
            losses: vec![],
        })
    }

    pub fn step(&mut self, batch: &ClsBatch, lr: f32) -> Result<f32> {
        self.step += 1;
        let (tok, lens, labels) = batch.to_tensors();
        let small = [
            HostTensor::vec_f32(self.t.clone()),
            HostTensor::vec_f32(self.m.clone()),
            HostTensor::vec_f32(self.v.clone()),
            tok,
            lens,
            labels,
            HostTensor::scalar_f32(lr),
            HostTensor::scalar_f32(self.step as f32),
        ];
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(small.len());
        for t in &small {
            bufs.push(self.engine.upload(t)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = vec![&self.base_buf];
        args.extend(bufs.iter());
        let out = self.exec.run_buffers(&args)?;
        self.t = out[0].f32s()?.to_vec();
        self.m = out[1].f32s()?.to_vec();
        self.v = out[2].f32s()?.to_vec();
        let loss = out[3].scalar()?;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Class predictions for a batch.
    pub fn predict(&self, batch: &ClsBatch) -> Result<Vec<i32>> {
        let exec = self.engine.load(&format!("cls_{}_{}_eval", self.cfg, self.method))?;
        let (tok, lens, _) = batch.to_tensors();
        let out = exec.run(&[
            HostTensor::vec_f32(self.base_host.clone()),
            HostTensor::vec_f32(self.t.clone()),
            tok,
            lens,
        ])?;
        let c = self.engine.manifest.config(&self.cfg)?;
        Ok(crate::eval::metrics::argmax_rows(out[0].f32s()?, c.n_classes))
    }
}
