//! Training infrastructure: loops, LR schedules, checkpoints.
//!
//! Two training paths share the [`Schedule`] and [`checkpoint`]
//! machinery:
//!
//! * [`trainer`] — the PJRT path, driving compiled `*_train` artifacts
//!   (requires `artifacts/manifest.json` + real xla bindings).
//! * [`host`] — the host-native differentiable path over the
//!   `TransformOp` gradient surface: trains on a bare checkout with no
//!   artifacts at all (the LR-robustness repro and the `train-host`
//!   subcommand run on it).

pub mod checkpoint;
pub mod host;
pub mod schedule;
pub mod trainer;

pub use host::HostTrainer;
pub use schedule::Schedule;
pub use trainer::{ClsTrainer, LmTrainer, Pretrainer};
