//! Training infrastructure: loops, LR schedules, checkpoints.

pub mod checkpoint;
pub mod schedule;
pub mod trainer;

pub use schedule::Schedule;
pub use trainer::{ClsTrainer, LmTrainer, Pretrainer};
