//! Learning-rate schedules (paper App. C.4: cosine annealing with warmup
//! for instruction tuning; constant elsewhere).

#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Const(f32),
    /// Linear warmup to `base` over `warmup` steps, cosine decay to ~0
    /// over the remaining `total − warmup` steps.
    Cosine { base: f32, warmup: u64, total: u64 },
}

impl Schedule {
    pub fn lr(&self, step: u64) -> f32 {
        match *self {
            Schedule::Const(lr) => lr,
            Schedule::Cosine { base, warmup, total } => {
                if step < warmup {
                    base * (step + 1) as f32 / warmup.max(1) as f32
                } else {
                    let t = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
                    let t = t.min(1.0);
                    base * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        assert_eq!(Schedule::Const(0.1).lr(0), 0.1);
        assert_eq!(Schedule::Const(0.1).lr(999), 0.1);
    }

    #[test]
    fn cosine_warms_up_and_decays() {
        let s = Schedule::Cosine { base: 1.0, warmup: 10, total: 110 };
        assert!(s.lr(0) < 0.2);
        assert!((s.lr(9) - 1.0).abs() < 0.11);
        assert!(s.lr(60) < 1.0);
        assert!(s.lr(109) < 0.01);
        // monotone decay after warmup
        assert!(s.lr(20) > s.lr(50));
        assert!(s.lr(50) > s.lr(100));
    }
}
