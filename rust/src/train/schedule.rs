//! Learning-rate schedules (paper App. C.4: cosine annealing with warmup
//! for instruction tuning; constant elsewhere).

#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Const(f32),
    /// Linear warmup to `base` over `warmup` steps, cosine decay to ~0
    /// over the remaining `total − warmup` steps.
    Cosine { base: f32, warmup: u64, total: u64 },
}

impl Schedule {
    /// LR at `step` (0-indexed). For `Cosine`, warmup ramps
    /// `base·(step+1)/warmup` and ends **exactly at `base`** on step
    /// `warmup − 1`; decay then starts strictly below the peak on step
    /// `warmup` (the old formula emitted `base` twice — a duplicated
    /// peak at the warmup/decay boundary the schedule tests pinned
    /// down) and reaches **exactly 0** on the final step `total − 1`,
    /// staying 0 for any later step.
    pub fn lr(&self, step: u64) -> f32 {
        match *self {
            Schedule::Const(lr) => lr,
            Schedule::Cosine { base, warmup, total } => {
                if step < warmup {
                    base * (step + 1) as f32 / warmup.max(1) as f32
                } else {
                    let span = (total.saturating_sub(warmup)).max(1) as f32;
                    let t = ((step - warmup + 1) as f32 / span).min(1.0);
                    base * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        assert_eq!(Schedule::Const(0.1).lr(0), 0.1);
        assert_eq!(Schedule::Const(0.1).lr(999), 0.1);
    }

    #[test]
    fn cosine_warms_up_and_decays() {
        let s = Schedule::Cosine { base: 1.0, warmup: 10, total: 110 };
        assert!(s.lr(0) < 0.2);
        assert!((s.lr(9) - 1.0).abs() < 0.11);
        assert!(s.lr(60) < 1.0);
        assert!(s.lr(109) < 0.01);
        // monotone decay after warmup
        assert!(s.lr(20) > s.lr(50));
        assert!(s.lr(50) > s.lr(100));
    }

    #[test]
    fn warmup_is_strictly_monotone_and_peaks_once() {
        let s = Schedule::Cosine { base: 1.0, warmup: 8, total: 40 };
        for i in 0..7 {
            assert!(s.lr(i) < s.lr(i + 1), "warmup not increasing at {i}");
        }
        // The peak is hit exactly once, at the last warmup step — the
        // old formula emitted `base` again on the first decay step.
        assert_eq!(s.lr(7), 1.0);
        assert!(s.lr(8) < 1.0, "duplicated peak at the warmup/decay boundary");
        for i in 8..39 {
            assert!(s.lr(i) > s.lr(i + 1), "decay not decreasing at {i}");
        }
    }

    #[test]
    fn cosine_endpoints_are_exact() {
        let s = Schedule::Cosine { base: 0.5, warmup: 4, total: 20 };
        // End of warmup == base, final step == 0, and the schedule
        // stays at 0 past `total` instead of going negative or rising.
        assert_eq!(s.lr(3), 0.5);
        assert!(s.lr(19).abs() < 1e-7, "lr(total-1) = {}", s.lr(19));
        assert!(s.lr(20).abs() < 1e-7);
        assert!(s.lr(1000).abs() < 1e-7);
        // Degenerate shapes do not divide by zero.
        let z = Schedule::Cosine { base: 1.0, warmup: 0, total: 1 };
        assert!(z.lr(0).is_finite());
        let w = Schedule::Cosine { base: 1.0, warmup: 5, total: 5 };
        assert!(w.lr(5).is_finite());
    }

    #[test]
    fn cosine_step_lr_table_regression() {
        // Pinned step → lr table for base=1, warmup=4, total=12:
        // warmup ramp ¼, ½, ¾, 1, then cosine over t = (i−3)/8.
        let s = Schedule::Cosine { base: 1.0, warmup: 4, total: 12 };
        let pi = std::f32::consts::PI;
        let want: Vec<f32> = vec![
            0.25,
            0.5,
            0.75,
            1.0,
            0.5 * (1.0 + (pi * 1.0 / 8.0).cos()),
            0.5 * (1.0 + (pi * 2.0 / 8.0).cos()),
            0.5 * (1.0 + (pi * 3.0 / 8.0).cos()),
            0.5 * (1.0 + (pi * 4.0 / 8.0).cos()),
            0.5 * (1.0 + (pi * 5.0 / 8.0).cos()),
            0.5 * (1.0 + (pi * 6.0 / 8.0).cos()),
            0.5 * (1.0 + (pi * 7.0 / 8.0).cos()),
            0.0,
        ];
        for (i, w) in want.iter().enumerate() {
            let got = s.lr(i as u64);
            assert!((got - w).abs() < 1e-6, "step {i}: {got} != {w}");
        }
    }
}
