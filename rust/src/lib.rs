//! # ETHER — Efficient Finetuning via Hyperplane Reflections
//!
//! A production-oriented reproduction of *ETHER: Efficient Finetuning of
//! Large-Scale Models with Hyperplane Reflections* (Bini et al., ICML
//! 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (build time): Pallas kernels for the block-parallel
//!   multiplicative weight transforms (`python/compile/kernels/`).
//! * **Layer 2** (build time): a functional JAX transformer with the full
//!   PEFT family (ETHER, ETHER+, OFT, Naive, LoRA, VeRA, full-FT) lowered
//!   AOT to HLO text artifacts (`python/compile/`).
//! * **Layer 3** (this crate): the runtime — PJRT execution of the
//!   artifacts, the training loop, the multi-adapter serving coordinator,
//!   host-side transform math for analysis, and the experiment drivers
//!   that regenerate every table and figure of the paper's evaluation.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `ether` binary is self-contained.
//!
//! Module map (see `DESIGN.md` for the full system inventory):
//!
//! | module         | contents                                              |
//! |----------------|-------------------------------------------------------|
//! | [`util`]       | offline substrates: JSON, RNG, CLI, pool, benchkit    |
//! | [`tensor`]     | dense f32 matrices, Gauss-Jordan solve, LU determinant|
//! | [`peft`]       | host-side transform family + distance / HE metrics    |
//! | [`runtime`]    | PJRT client, manifest, typed executables, mock engine |
//! | [`data`]       | synthetic workloads (corpus, SynthGLUE, instructions, |
//! |                | generation control, subject-driven)                   |
//! | [`train`]      | PJRT + host-native training, LR schedules, checkpoints|
//! | [`coordinator`]| adapter registry, fair scheduler, loadgen, serving    |
//! | [`sim`]        | discrete-event fleet simulator + offline auto-tuning  |
//! | [`eval`]       | metric suite + evaluation harnesses                   |
//! | [`exp`]        | one driver per paper table / figure                   |

pub mod util;
pub mod tensor;
pub mod peft;
pub mod runtime;
pub mod data;
pub mod train;
pub mod coordinator;
pub mod sim;
pub mod eval;
pub mod exp;

/// Canonical location of the AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$ETHER_ARTIFACTS` (via the
/// [`util::runtimecfg::RuntimeCfg`] snapshot) if set, otherwise walk up
/// from the current directory looking for `artifacts/manifest.json`
/// (so tests and benches work from any cargo target dir).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Some(p) = util::runtimecfg::RuntimeCfg::get().artifacts.as_ref() {
        return p.clone();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}

/// Resolve the reports output directory (created on demand). Creation
/// failures are logged through the `util::logging` facade instead of
/// being silently swallowed — the caller's subsequent write will then
/// fail with a path that has already been explained in the log.
pub fn reports_dir() -> std::path::PathBuf {
    let dir = artifacts_dir().parent().map(|p| p.join("reports")).unwrap_or_else(|| "reports".into());
    if let Err(e) = std::fs::create_dir_all(&dir) {
        util::logging::init();
        log::error!("could not create reports dir {dir:?}: {e}");
    }
    dir
}
