//! Analysis metrics from the paper: transformation distance (Fig. 4),
//! weights distance (Fig. 4), and hyperspherical energy (Fig. 7 / §5.3).

use anyhow::Result;

use crate::peft::apply::ModelDims;
use crate::peft::flat::Layout;
use crate::peft::op::resolve_params;
use crate::peft::{adapted_matrices, registry, MethodSpec};
use crate::tensor::{l2_dist, Mat};

/// Hyperspherical energy of a weight matrix: `Σ_{i<j} ‖ŵ_i − ŵ_j‖⁻¹`
/// over unit-normalized rows (Liu et al. MHE with s = 1, as used by OFT).
/// Rows are subsampled to `max_rows` for large matrices.
pub fn hyperspherical_energy(w: &Mat, max_rows: usize) -> f64 {
    let take = w.rows.min(max_rows);
    let stride = (w.rows / take).max(1);
    let rows: Vec<Vec<f64>> = (0..take)
        .map(|i| {
            let r = w.row(i * stride);
            let n = (r.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt().max(1e-12);
            r.iter().map(|&x| x as f64 / n).collect()
        })
        .collect();
    let mut he = 0.0;
    for i in 0..rows.len() {
        for j in i + 1..rows.len() {
            let d2: f64 = rows[i]
                .iter()
                .zip(&rows[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            he += 1.0 / d2.sqrt().max(1e-9);
        }
    }
    // ×2 for the symmetric pair convention used in the OFT paper.
    2.0 * he
}

/// Total HE over all adapted matrices of a model (flat base weights).
pub fn model_he(
    dims: ModelDims,
    base: &[f32],
    base_layout: &Layout,
    max_rows: usize,
) -> Result<f64> {
    let mut total = 0.0;
    for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
        for l in 0..dims.n_layers {
            let w = crate::peft::apply::weight_matrix(base, base_layout, name, l, d, f)?;
            total += hyperspherical_energy(&w, max_rows);
        }
    }
    Ok(total)
}

/// The paper's "Transformation Distance" (Fig. 4): aggregate
/// `‖T − I‖_F` over layers and matrices.
///
/// Registry-dispatched: each op's
/// [`crate::peft::op::TransformOp::distance_sq`] materializes the
/// distance of its own transform from the neutral element — `‖T − I‖_F`
/// for multiplicative methods (left/right factors on the identity),
/// `‖ΔW‖_F` for additive methods (transform of the zero matrix) —
/// reported on the same axis as in the paper.
pub fn transformation_distance(
    dims: ModelDims,
    spec: &MethodSpec,
    peft: &[f32],
    peft_layout: &Layout,
) -> Result<f64> {
    let op = registry::op_for(spec.kind);
    let mut acc = 0.0f64;
    for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
        for l in 0..dims.n_layers {
            let p = resolve_params(op, spec, peft, peft_layout, name, l, d, f)?;
            acc += op.distance_sq(spec, &p, d, f)?;
        }
    }
    Ok(acc.sqrt())
}

/// The paper's "Weights Distance" (Fig. 4): ‖W′ − W‖₂ over all weights.
pub fn weights_distance(base: &[f32], merged: &[f32]) -> f64 {
    l2_dist(base, merged)
}

/// Closed form for ETHER's transformation distance: every block is an
/// exact reflection, so the total is `2·√(L · |mats| · n)` (paper Eq. 2
/// generalized to the block-diagonal, multi-layer setting).
pub fn ether_expected_distance(dims: ModelDims, n_blocks: usize) -> f64 {
    let mats = adapted_matrices(dims.d_model, dims.d_ff).len();
    2.0 * ((dims.n_layers * mats * n_blocks) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peft::apply::peft_layout_for;
    use crate::util::rng::Rng;

    fn dims() -> ModelDims {
        ModelDims { d_model: 16, d_ff: 32, n_layers: 2 }
    }

    #[test]
    fn he_of_orthogonal_rows_is_known() {
        // Rows of I are mutually at distance √2: HE = 2 · C(n,2) / √2.
        let eye = Mat::eye(8);
        let he = hyperspherical_energy(&eye, 8);
        let want = 2.0 * (8.0 * 7.0 / 2.0) / 2f64.sqrt();
        assert!((he - want).abs() < 1e-6, "{he} vs {want}");
    }

    #[test]
    fn he_invariant_under_householder() {
        // Orthogonal transforms preserve pairwise angles ⇒ HE unchanged
        // (the paper's §3.2 observation that ETHER retains HE).
        let mut rng = Rng::new(0);
        let w = Mat::randn(24, 24, 1.0, &mut rng);
        let u = rng.normal_vec(24, 1.0);
        // Right-multiplication by an orthogonal map preserves row norms
        // and pairwise distances of rows.
        let h = crate::peft::transforms::householder_dense(&u, 1);
        let wt = w.matmul(&h);
        let he0 = hyperspherical_energy(&w, 24);
        let he1 = hyperspherical_energy(&wt, 24);
        assert!((he0 - he1).abs() / he0 < 1e-6, "{he0} {he1}");
    }

    #[test]
    fn ether_distance_matches_closed_form() {
        let dims = dims();
        let spec = MethodSpec::parse("ether_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(1);
        let peft = rng.normal_vec(pl.total, 1.0);
        let dist = transformation_distance(dims, &spec, &peft, &pl).unwrap();
        let want = ether_expected_distance(dims, 4);
        assert!((dist - want).abs() < 1e-3, "{dist} vs {want}");
    }

    #[test]
    fn etherplus_distance_bounded_by_ether() {
        // max ‖H⁺ − I‖ ≤ max ‖H − I‖ (paper §3.3).
        let dims = dims();
        let ep = MethodSpec::parse("etherplus_n4").unwrap();
        let pl = peft_layout_for(dims, &ep);
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            let peft = rng.normal_vec(pl.total, 1.0);
            let dist = transformation_distance(dims, &ep, &peft, &pl).unwrap();
            // two-sided: left bound 2√(L·mats·n) plus right bound same ⇒ √2×
            let bound = 2f64.sqrt() * ether_expected_distance(dims, 4) + 1e-6;
            assert!(dist <= bound, "{dist} > {bound}");
        }
    }

    #[test]
    fn naive_distance_grows_with_scale() {
        let dims = dims();
        let spec = MethodSpec::parse("naive_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(3);
        let peft: Vec<f32> = rng.normal_vec(pl.total, 1.0);
        let d1 = transformation_distance(dims, &spec, &peft, &pl).unwrap();
        let big: Vec<f32> = peft.iter().map(|x| x * 10.0).collect();
        let d10 = transformation_distance(dims, &spec, &big, &pl).unwrap();
        assert!(d10 > 5.0 * d1, "{d10} vs {d1}");
    }
}
