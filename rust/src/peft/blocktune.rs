//! Deterministic `n_blocks` auto-tuner.
//!
//! The paper's Table 1 observation: block-diagonal transforms get
//! *cheaper* as the block count `n` grows (each of the `n` reflections
//! acts on a `d/n × d/n` slab, so the `H·W` product is `O(d²f/n)`),
//! while per-block dispatch overhead grows linearly in `n` — upstream
//! lands on `n = 32` as the sweet spot at Llama-2-7B scale. This module
//! turns that trade-off into a closed-form cost model and a
//! **deterministic ranking** (same discipline as `sim::tune`: pure
//! arithmetic over a fixed candidate grid, ties broken toward smaller
//! `n`), so the pick is identical across runs, machines, and thread
//! counts — CI can pin it.
//!
//! Precedence for the effective block count is the standard knob chain
//! (`explicit > ETHER_NBLOCKS > tuned default`) via
//! [`auto_n_blocks`]. The `table1_blocks` bench emits the ranked table
//! plus the measured wallclock per candidate as
//! `BENCH_table1_blocks.json`.

use crate::util::runtimecfg::{resolve, RuntimeCfg};

/// Default per-FLOP cost (ns) of the host merge kernels — the order of
/// magnitude measured by `transform_apply` on the CI hosts. Only the
/// *ratio* to [`DEFAULT_BLOCK_OVERHEAD_NS`] matters for the ranking.
pub const DEFAULT_FLOP_NS: f64 = 5e-4;

/// Default fixed cost (ns) a block adds per apply: dispatch, the
/// reflection's small-vector setup, and cache refill at slab edges.
pub const DEFAULT_BLOCK_OVERHEAD_NS: f64 = 3.4e4;

/// One candidate block count with its modeled cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockCost {
    pub n: usize,
    /// FLOPs of one blocked transform apply over a `d×f` matrix.
    pub flops: f64,
    /// Modeled wallclock (ns): `flops·flop_ns + n·overhead_ns`.
    pub est_ns: f64,
}

/// Power-of-two block counts that evenly divide `d_model`, capped at
/// 256 (the paper's largest bdmm sweep point).
pub fn candidates(d_model: usize) -> Vec<usize> {
    (0..=8)
        .map(|k| 1usize << k)
        .filter(|&n| n <= d_model && d_model % n == 0)
        .collect()
}

/// Closed-form cost of one blocked transform apply at block count `n`
/// over a `d×f` weight: the block-diagonal product is `2·d²·f/n` FLOPs
/// (each of the `n` blocks multiplies a `d/n × d/n` reflection into its
/// slab), plus `4·d·f` for the rank-1 reflection construction, plus
/// fixed per-block overhead.
pub fn block_cost(d: usize, f: usize, n: usize, flop_ns: f64, overhead_ns: f64) -> BlockCost {
    let (df, ff, nf) = (d as f64, f as f64, n as f64);
    let flops = 2.0 * df * df * ff / nf + 4.0 * df * ff;
    BlockCost { n, flops, est_ns: flops * flop_ns + nf * overhead_ns }
}

/// Rank every candidate for `d×f` by modeled cost, cheapest first.
/// Pure arithmetic over a fixed grid — the ranking is bit-deterministic
/// across runs and thread counts, with exact-cost ties broken toward
/// the smaller `n`.
pub fn tune_nblocks(d: usize, f: usize, flop_ns: f64, overhead_ns: f64) -> Vec<BlockCost> {
    let mut ranked: Vec<BlockCost> =
        candidates(d).into_iter().map(|n| block_cost(d, f, n, flop_ns, overhead_ns)).collect();
    ranked.sort_by(|a, b| {
        a.est_ns.total_cmp(&b.est_ns).then(a.n.cmp(&b.n))
    });
    ranked
}

/// The tuner's winner for `d×f` under the default cost model.
pub fn tuned_n_blocks(d: usize, f: usize) -> usize {
    tune_nblocks(d, f, DEFAULT_FLOP_NS, DEFAULT_BLOCK_OVERHEAD_NS)[0].n
}

/// Effective block count: `explicit > ETHER_NBLOCKS > tuned winner`.
/// An **explicit** argument that divides `d` is honored as-is — it is a
/// schema-valid caller choice, even off the power-of-two ≤256 candidate
/// grid (e.g. `n = 512` at `d = 4096`). Only values that would violate
/// the divisibility requirement — a non-divisor explicit, or any env
/// override off the grid — snap to the nearest valid candidate rather
/// than erroring.
pub fn auto_n_blocks(explicit: Option<usize>, d: usize, f: usize) -> usize {
    auto_n_blocks_with(explicit, RuntimeCfg::get().n_blocks, d, f)
}

/// [`auto_n_blocks`] over an explicit env value — the testable core.
pub fn auto_n_blocks_with(
    explicit: Option<usize>,
    env: Option<usize>,
    d: usize,
    f: usize,
) -> usize {
    // Precedence is `explicit > env > tuned`, and an explicit divisor of
    // `d` is already schema-valid: return it untouched instead of
    // snapping a deliberate caller choice onto the candidate grid.
    if let Some(n) = explicit {
        if n > 0 && n <= d && d % n == 0 {
            return n;
        }
    }
    let n = resolve(explicit, env, tuned_n_blocks(d, f));
    // Snap to the nearest (by ratio, ties downward) valid candidate.
    let cands = candidates(d);
    cands
        .iter()
        .copied()
        .min_by(|&a, &b| {
            let ra = (a as f64 / n as f64).max(n as f64 / a as f64);
            let rb = (b as f64 / n as f64).max(n as f64 / b as f64);
            ra.total_cmp(&rb).then(a.cmp(&b))
        })
        .unwrap_or(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_are_power_of_two_divisors() {
        assert_eq!(candidates(4096), vec![1, 2, 4, 8, 16, 32, 64, 128, 256]);
        assert_eq!(candidates(48), vec![1, 2, 4, 8, 16]);
        assert_eq!(candidates(1), vec![1]);
    }

    #[test]
    fn tuner_pins_paper_scale_winner() {
        // At Llama-2-7B-ish width the model lands on the paper's n=32.
        assert_eq!(tuned_n_blocks(4096, 4096), 32);
        // At toy dims the overhead term dominates: one block wins.
        assert_eq!(tuned_n_blocks(64, 64), 1);
    }

    #[test]
    fn ranking_is_deterministic_and_monotone_in_model() {
        let a = tune_nblocks(4096, 4096, DEFAULT_FLOP_NS, DEFAULT_BLOCK_OVERHEAD_NS);
        let b = tune_nblocks(4096, 4096, DEFAULT_FLOP_NS, DEFAULT_BLOCK_OVERHEAD_NS);
        assert_eq!(a, b, "pure-arithmetic ranking must be bit-stable");
        // est_ns ascending.
        assert!(a.windows(2).all(|w| w[0].est_ns <= w[1].est_ns));
        // Every candidate appears exactly once.
        let mut ns: Vec<usize> = a.iter().map(|c| c.n).collect();
        ns.sort_unstable();
        assert_eq!(ns, candidates(4096));
    }

    #[test]
    fn auto_precedence_and_snapping() {
        // explicit > env > tuned.
        assert_eq!(auto_n_blocks_with(Some(8), Some(64), 4096, 4096), 8);
        assert_eq!(auto_n_blocks_with(None, Some(64), 4096, 4096), 64);
        assert_eq!(auto_n_blocks_with(None, None, 4096, 4096), 32);
        // Invalid override snaps to the nearest valid candidate.
        assert_eq!(auto_n_blocks_with(None, Some(48), 4096, 4096), 64);
        assert_eq!(auto_n_blocks_with(None, Some(1000), 64, 64), 64);
    }

    #[test]
    fn explicit_divisor_is_honored_env_still_snaps() {
        // Explicit n=512 divides d=4096 but sits past the ≤256 candidate
        // grid: a schema-valid caller choice must be honored, not
        // silently snapped to 256.
        assert_eq!(auto_n_blocks_with(Some(512), None, 4096, 4096), 512);
        assert_eq!(auto_n_blocks_with(Some(512), Some(16), 4096, 4096), 512);
        // Non-power-of-two explicit divisors are honored too.
        assert_eq!(auto_n_blocks_with(Some(3), None, 48, 48), 3);
        // An explicit NON-divisor would violate the schema: it still
        // snaps (48 ∤ 4096 → nearest-by-ratio candidate 64).
        assert_eq!(auto_n_blocks_with(Some(48), None, 4096, 4096), 64);
        // The env override always snaps, even when it divides d — only
        // the explicit argument may leave the candidate grid.
        assert_eq!(auto_n_blocks_with(None, Some(512), 4096, 4096), 256);
    }
}
