//! Transform registry: the **single** `MethodKind` dispatch site.
//!
//! Everything else in the crate reaches a method's behaviour through
//! [`op_for`] (or [`by_token`] when parsing names); per-method `match`
//! arms are confined to this module and the trait impls in
//! [`crate::peft::op`]. The `match` in [`op_for`] is exhaustive, so
//! adding a [`MethodKind`] variant without registering its op is a
//! compile error — the property `rust/tests/op_registry_props.rs` locks
//! in from the outside.

use crate::peft::op::{
    DeloraOp, EtherOp, EtherPlusOp, FullOp, HyperAdaptOp, LoraOp, NaiveOp, NoneOp, OftOp,
    TransformOp, VeraOp,
};
use crate::peft::MethodKind;

/// Every registered family member, in canonical (parse-priority) order.
pub const ALL_KINDS: [MethodKind; 10] = [
    MethodKind::Ether,
    MethodKind::EtherPlus,
    MethodKind::Oft,
    MethodKind::Naive,
    MethodKind::Lora,
    MethodKind::Vera,
    MethodKind::Delora,
    MethodKind::HyperAdapt,
    MethodKind::Full,
    MethodKind::None,
];

/// Look up the transform op implementing `kind`. The one canonical
/// per-method dispatch in the crate.
pub fn op_for(kind: MethodKind) -> &'static dyn TransformOp {
    match kind {
        MethodKind::Ether => &EtherOp,
        MethodKind::EtherPlus => &EtherPlusOp,
        MethodKind::Oft => &OftOp,
        MethodKind::Naive => &NaiveOp,
        MethodKind::Lora => &LoraOp,
        MethodKind::Vera => &VeraOp,
        MethodKind::Delora => &DeloraOp,
        MethodKind::HyperAdapt => &HyperAdaptOp,
        MethodKind::Full => &FullOp,
        MethodKind::None => &NoneOp,
    }
}

/// Look up an op by its name token (`"ether"`, `"lora"`, …).
pub fn by_token(token: &str) -> Option<&'static dyn TransformOp> {
    ALL_KINDS.iter().map(|&k| op_for(k)).find(|op| op.token() == token)
}

/// Every kind whose op implements the gradient surface
/// ([`TransformOp::supports_grad`]) — the family the host trainer,
/// the `train_step` bench and the gradcheck harness iterate over.
pub fn grad_kinds() -> Vec<MethodKind> {
    ALL_KINDS.iter().copied().filter(|&k| op_for(k).supports_grad()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_kind_registers_its_own_op() {
        for &kind in ALL_KINDS.iter() {
            let op = op_for(kind);
            assert_eq!(op.kind(), kind, "{:?}", kind);
            let again = by_token(op.token()).expect("token lookup");
            assert_eq!(again.kind(), kind);
        }
    }

    #[test]
    fn grad_family_is_the_host_mergeable_parametric_family() {
        // Differentiable ⇒ host weights + activation forward exist; the
        // exact member list is pinned from the outside by
        // rust/tests/grad_props.rs.
        let kinds = grad_kinds();
        assert!(!kinds.is_empty());
        for kind in kinds {
            let op = op_for(kind);
            assert!(op.host_mergeable(), "{kind:?}: grads need host weights");
            assert!(op.supports_activations(), "{kind:?}: grads need the activation forward");
            assert!(!op.is_identity(), "{kind:?}: the identity has no parameters to train");
        }
    }

    #[test]
    fn tokens_are_unique() {
        let mut tokens: Vec<&str> = ALL_KINDS.iter().map(|&k| op_for(k).token()).collect();
        tokens.sort_unstable();
        tokens.dedup();
        assert_eq!(tokens.len(), ALL_KINDS.len());
    }
}
