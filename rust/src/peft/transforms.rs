//! Host-tensor implementations of every weight transform in the family.
//!
//! Math mirrors the Layer-1 Pallas kernels exactly (same guarded
//! normalization, same block semantics); see `python/compile/kernels/`.
//!
//! Since the blocked-engine refactor, each multiplicative transform has
//! two implementations:
//!
//! * a **blocked parallel** engine (the default public functions): the
//!   output is split into column tiles (rows, for the right-side
//!   reflection) processed by `parallel_for_chunks` workers, with the
//!   per-column reductions accumulated in f64. Every output element is a
//!   fixed-order function of one column of `W`, so results are
//!   **bit-identical** regardless of thread count or tile boundaries —
//!   the property `rust/tests/merge_parallel.rs` locks in.
//! * a **serial scalar reference** (`*_serial`): the original per-row
//!   f32 implementation, kept as the parity oracle and as the baseline
//!   for the blocked-vs-serial benchmark cases.
//!
//! The `*_into` slice kernels are the single-threaded building blocks
//! `peft::apply::MergePlan` runs per (matrix, layer) work item, writing
//! straight into the merged-weight buffer without intermediate `Mat`
//! clones.
//!
//! The **batched GEMM family** serves the activation hot path
//! (`y = T(W)·X`, `X` = column-stacked request vectors): the
//! register-tiled microkernel behind [`matmul_tiled_into`] /
//! [`matmul_tiled_par`] retiles the loop nest for cache and register
//! reuse while keeping [`matmul_acc_into`]'s fixed-order f64 reduction
//! per output element, so the tiled kernels are **bit-identical** to the
//! serial oracle for any tile geometry and any thread count —
//! `rust/tests/kernel_props.rs` is the property gate. See
//! `docs/tiled-kernels.md` for the walkthrough.

use crate::tensor::{solve, Mat};
use crate::util::pool::{parallel_for_chunks, parallel_for_chunks_opt, SendPtr};

/// Guard used by the kernels' in-place normalization (must match
/// `kernels/ether.py::NORM_EPS`).
pub const NORM_EPS: f64 = 1e-12;

/// Column-tile width for the parallel drivers: wide enough to amortize
/// thread spawn, narrow enough to split the typical d_model range.
const COL_TILE: usize = 64;

/// Row-chunk floor for the (row-parallel) right-side reflection.
const ROW_TILE: usize = 8;

/// û = u · rsqrt(Σu² + ε).
pub fn normalize(u: &[f32]) -> Vec<f32> {
    let s: f64 = u.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let r = 1.0 / (s + NORM_EPS).sqrt();
    u.iter().map(|&x| (x as f64 * r) as f32).collect()
}

/// Normalize all `n` blocks of `u` in one pass (blocks tile `u` evenly).
///
/// Hard-asserts the tiling in release builds too: a malformed adapter
/// vector must fail loudly here rather than silently mis-blocking the
/// reflection (the old `debug_assert` let release builds normalize
/// against truncated blocks). Upstream schema validation makes this
/// unreachable from the public merge paths.
pub(crate) fn normalize_blocks(u: &[f32], n: usize) -> Vec<f32> {
    assert!(n > 0, "normalize_blocks: n must be > 0");
    assert!(
        u.len() % n == 0,
        "normalize_blocks: {} parameters do not tile into {n} blocks",
        u.len()
    );
    let db = u.len() / n;
    let mut out = Vec::with_capacity(u.len());
    for b in 0..n {
        out.extend_from_slice(&normalize(&u[b * db..(b + 1) * db]));
    }
    out
}

// ---------------------------------------------------------------------------
// Column-tile kernels. Each writes columns [c0, c1) of the output; every
// element depends only on its own column of `w` with a fixed reduction
// order, so any tiling of [0, f) produces identical bits.
// ---------------------------------------------------------------------------

/// Columns `[c0, c1)` of `H^B W` (Eq. 1): per block, `w − 2 û (ûᵀ w)`.
///
/// # Safety
/// `out` must point at a `uh.len() × f` buffer, and no other thread may
/// concurrently access columns `[c0, c1)` of it.
unsafe fn ether_cols(uh: &[f32], n: usize, w: &[f32], f: usize, out: *mut f32, c0: usize, c1: usize) {
    let d = uh.len();
    let db = d / n;
    let width = c1 - c0;
    let mut proj = vec![0.0f64; width];
    for b in 0..n {
        proj.fill(0.0);
        for r in 0..db {
            let off = (b * db + r) * f;
            let uv = uh[b * db + r] as f64;
            let row = &w[off + c0..off + c1];
            for (p, &x) in proj.iter_mut().zip(row) {
                *p += uv * x as f64;
            }
        }
        for r in 0..db {
            let off = (b * db + r) * f;
            let uv = 2.0 * uh[b * db + r] as f64;
            let row = &w[off + c0..off + c1];
            for (i, (&x, p)) in row.iter().zip(&proj).enumerate() {
                *out.add(off + c0 + i) = (x as f64 - uv * p) as f32;
            }
        }
    }
}

/// Columns `[c0, c1)` of `H⁺ W`, `H⁺ = I − ûûᵀ + v̂v̂ᵀ` (§3.3).
///
/// # Safety
/// Same contract as [`ether_cols`].
#[allow(clippy::too_many_arguments)]
unsafe fn ether_plus_left_cols(
    uh: &[f32],
    vh: &[f32],
    n: usize,
    w: &[f32],
    f: usize,
    out: *mut f32,
    c0: usize,
    c1: usize,
) {
    let db = uh.len() / n;
    let width = c1 - c0;
    let mut pu = vec![0.0f64; width];
    let mut pv = vec![0.0f64; width];
    for b in 0..n {
        pu.fill(0.0);
        pv.fill(0.0);
        for r in 0..db {
            let off = (b * db + r) * f;
            let uv = uh[b * db + r] as f64;
            let vv = vh[b * db + r] as f64;
            let row = &w[off + c0..off + c1];
            for (i, &x) in row.iter().enumerate() {
                pu[i] += uv * x as f64;
                pv[i] += vv * x as f64;
            }
        }
        for r in 0..db {
            let off = (b * db + r) * f;
            let uv = uh[b * db + r] as f64;
            let vv = vh[b * db + r] as f64;
            let row = &w[off + c0..off + c1];
            for (i, &x) in row.iter().enumerate() {
                *out.add(off + c0 + i) = (x as f64 - uv * pu[i] + vv * pv[i]) as f32;
            }
        }
    }
}

/// Columns `[c0, c1)` of the block-diagonal multiply `Q^B W`, optionally
/// fused with the OFT magnitude-refit column scaling `(1 + mag[c])`.
///
/// # Safety
/// Same contract as [`ether_cols`] (buffer is `n·k × f`).
unsafe fn bdmm_cols(
    blocks: &[Mat],
    w: &[f32],
    f: usize,
    scale: Option<&[f32]>,
    out: *mut f32,
    c0: usize,
    c1: usize,
) {
    let k = blocks[0].rows;
    let width = c1 - c0;
    let mut acc = vec![0.0f64; width];
    for (b, q) in blocks.iter().enumerate() {
        for i in 0..k {
            acc.fill(0.0);
            for j in 0..k {
                let qv = q.at(i, j) as f64;
                if qv == 0.0 {
                    continue;
                }
                let off = (b * k + j) * f;
                let row = &w[off + c0..off + c1];
                for (a, &x) in acc.iter_mut().zip(row) {
                    *a += qv * x as f64;
                }
            }
            let off = (b * k + i) * f;
            match scale {
                Some(mag) => {
                    for (idx, a) in acc.iter().enumerate() {
                        let m = 1.0 + mag[c0 + idx] as f64;
                        *out.add(off + c0 + idx) = (*a * m) as f32;
                    }
                }
                None => {
                    for (idx, a) in acc.iter().enumerate() {
                        *out.add(off + c0 + idx) = *a as f32;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Single-threaded slice kernels for MergePlan work items (full width).
// ---------------------------------------------------------------------------

/// `out = H^B w` over a full `d×f` slice pair (pre-normalized `uh`).
pub(crate) fn ether_into(uh: &[f32], n: usize, w: &[f32], f: usize, out: &mut [f32]) {
    debug_assert_eq!(w.len(), out.len());
    debug_assert_eq!(w.len(), uh.len() * f);
    // SAFETY: exclusive &mut access to the whole buffer, single thread.
    unsafe { ether_cols(uh, n, w, f, out.as_mut_ptr(), 0, f) }
}

/// `out = H⁺ w` over a full `d×f` slice pair (pre-normalized `uh`, `vh`).
pub(crate) fn ether_plus_left_into(
    uh: &[f32],
    vh: &[f32],
    n: usize,
    w: &[f32],
    f: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(w.len(), out.len());
    // SAFETY: exclusive &mut access to the whole buffer, single thread.
    unsafe { ether_plus_left_cols(uh, vh, n, w, f, out.as_mut_ptr(), 0, f) }
}

/// `out = Q^B w` (optionally magnitude-refit) over a full slice pair.
pub(crate) fn bdmm_into(blocks: &[Mat], w: &[f32], f: usize, scale: Option<&[f32]>, out: &mut [f32]) {
    debug_assert_eq!(w.len(), out.len());
    // SAFETY: exclusive &mut access to the whole buffer, single thread.
    unsafe { bdmm_cols(blocks, w, f, scale, out.as_mut_ptr(), 0, f) }
}

/// Apply the right-side relaxed reflection `· H̃⁺` to contiguous rows in
/// place (row-local: each row only mixes within its own column blocks).
pub(crate) fn ether_plus_right_rows(rows: &mut [f32], f: usize, uh: &[f32], vh: &[f32], n: usize) {
    debug_assert_eq!(rows.len() % f, 0);
    let fb = f / n;
    for row in rows.chunks_mut(f) {
        for b in 0..n {
            let seg = &mut row[b * fb..(b + 1) * fb];
            let ub = &uh[b * fb..(b + 1) * fb];
            let vb = &vh[b * fb..(b + 1) * fb];
            let mut pu = 0.0f64;
            let mut pv = 0.0f64;
            for c in 0..fb {
                pu += seg[c] as f64 * ub[c] as f64;
                pv += seg[c] as f64 * vb[c] as f64;
            }
            for c in 0..fb {
                seg[c] = (seg[c] as f64 - pu * ub[c] as f64 + pv * vb[c] as f64) as f32;
            }
        }
    }
}

/// `out (d×m) = W (d×f) · X (f×m)` with the per-element reduction over
/// the shared dimension accumulated in f64 in a fixed order — the
/// activation-path analogue of the merge kernels' determinism contract
/// (bit-identical regardless of how callers parallelize *across* calls).
///
/// This is the **serial scalar oracle** of the GEMM family: the tiled
/// microkernels ([`matmul_tiled_into`], [`matmul_tiled_par`]) must agree
/// with it bit-for-bit, which `rust/tests/kernel_props.rs` pins.
pub fn matmul_acc_into(w: &[f32], x: &[f32], d: usize, f: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(w.len(), d * f);
    debug_assert_eq!(x.len(), f * m);
    debug_assert_eq!(out.len(), d * m);
    for i in 0..d {
        let wrow = &w[i * f..(i + 1) * f];
        let orow = &mut out[i * m..(i + 1) * m];
        for (c, o) in orow.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for (j, &wv) in wrow.iter().enumerate() {
                acc += wv as f64 * x[j * m + c] as f64;
            }
            *o = acc as f32;
        }
    }
}

/// Thread-aware variant of [`matmul_acc_into`], row-parallel: workers
/// take disjoint row ranges of `out` and every output element keeps the
/// same fixed-order f64 reduction, so the result is **bit-identical for
/// any thread count** (including `Some(1)`, the serial pinning). This is
/// the forward-recompute kernel of the `TransformOp` gradient surface —
/// grad kernels re-derive their intermediates (`z = W·x`) instead of
/// caching them, trading FLOPs for a stateless backward API.
pub(crate) fn matmul_par(
    threads: Option<usize>,
    w: &[f32],
    x: &[f32],
    d: usize,
    f: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(w.len(), d * f);
    debug_assert_eq!(x.len(), f * m);
    debug_assert_eq!(out.len(), d * m);
    let ptr = SendPtr::new(out.as_mut_ptr());
    parallel_for_chunks_opt(threads, d, 16, |r0, r1| {
        ptr.claim(r0 * m, (r1 - r0) * m);
        for i in r0..r1 {
            let wrow = &w[i * f..(i + 1) * f];
            // SAFETY: workers receive disjoint row ranges of `out`.
            let orow = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(i * m), m) };
            for (c, o) in orow.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for (j, &wv) in wrow.iter().enumerate() {
                    acc += wv as f64 * x[j * m + c] as f64;
                }
                *o = acc as f32;
            }
        }
    });
}

/// Register-tile height of the batched GEMM microkernel: rows of `W`
/// held live per step. 4×8 f64 accumulators fit comfortably in the 16
/// callee-visible vector registers of x86-64/aarch64 baselines.
pub const GEMM_MR: usize = 4;

/// Register-tile width of the batched GEMM microkernel: columns of `X`
/// held live per step.
pub const GEMM_NR: usize = 8;

/// Rows `[r0, r1)` of `out = W·X` through the register-tiled microkernel.
///
/// The loop nest is retiled for locality — `GEMM_MR` rows of `W` ×
/// `GEMM_NR` columns of `X` accumulate in a register-resident f64 block
/// while the shared dimension streams once — but every output element
/// still reduces over `j = 0..f` in the exact order of
/// [`matmul_acc_into`], and f64 adds/muls are IEEE-exact per step, so
/// the result is **bit-identical** to the serial oracle for any tile
/// geometry. Cache story: one `f×GEMM_NR` column panel of `X` stays hot
/// across all row tiles; `W` streams `⌈m/GEMM_NR⌉` times instead of the
/// oracle's `m` times.
///
/// # Safety
/// `out` must point at a `d×m` row-major buffer and no other thread may
/// concurrently access rows `[r0, r1)` of it.
unsafe fn matmul_tiled_rows(
    w: &[f32],
    x: &[f32],
    f: usize,
    m: usize,
    out: *mut f32,
    r0: usize,
    r1: usize,
) {
    let mut acc = [[0.0f64; GEMM_NR]; GEMM_MR];
    let mut c0 = 0;
    while c0 < m {
        let nc = (m - c0).min(GEMM_NR);
        let mut i0 = r0;
        while i0 < r1 {
            let nr = (r1 - i0).min(GEMM_MR);
            for row in acc.iter_mut().take(nr) {
                row[..nc].fill(0.0);
            }
            for j in 0..f {
                let xrow = &x[j * m + c0..j * m + c0 + nc];
                for (r, arow) in acc.iter_mut().enumerate().take(nr) {
                    let wv = w[(i0 + r) * f + j] as f64;
                    for (a, &xv) in arow.iter_mut().zip(xrow) {
                        *a += wv * xv as f64;
                    }
                }
            }
            for (r, arow) in acc.iter().enumerate().take(nr) {
                for (c, &a) in arow.iter().enumerate().take(nc) {
                    *out.add((i0 + r) * m + c0 + c) = a as f32;
                }
            }
            i0 += nr;
        }
        c0 += nc;
    }
}

/// `out (d×m) = W (d×f) · X (f×m)` through the register-tiled
/// microkernel, single-threaded. Bit-identical to [`matmul_acc_into`]
/// (same fixed-order f64 reduction per element) — the fast drop-in the
/// `TransformOp` activation kernels use for their base products.
pub fn matmul_tiled_into(w: &[f32], x: &[f32], d: usize, f: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(w.len(), d * f);
    debug_assert_eq!(x.len(), f * m);
    debug_assert_eq!(out.len(), d * m);
    // SAFETY: exclusive &mut access to the whole buffer, single thread.
    unsafe { matmul_tiled_rows(w, x, f, m, out.as_mut_ptr(), 0, d) }
}

/// Thread-parallel driver of the tiled microkernel: workers take
/// disjoint row ranges (chunk floor [`GEMM_MR`]·4) and each runs
/// [`matmul_tiled_into`]'s inner kernel, so the result is bit-identical
/// for **any** thread count (`Some(1)` pins serial execution, `None`
/// uses the ambient pool) and bit-identical to [`matmul_acc_into`].
pub fn matmul_tiled_par(
    threads: Option<usize>,
    w: &[f32],
    x: &[f32],
    d: usize,
    f: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(w.len(), d * f);
    debug_assert_eq!(x.len(), f * m);
    debug_assert_eq!(out.len(), d * m);
    let ptr = SendPtr::new(out.as_mut_ptr());
    parallel_for_chunks_opt(threads, d, GEMM_MR * 4, |r0, r1| {
        ptr.claim(r0 * m, (r1 - r0) * m);
        // SAFETY: workers receive disjoint row ranges of `out`.
        unsafe { matmul_tiled_rows(w, x, f, m, ptr.get(), r0, r1) }
    });
}

/// `out (f×m) = Wᵀ · G` for `W` (`d×f`) and `G` (`d×m`): the
/// input-gradient kernel (`∂L/∂x = Wᵀ·∂L/∂y`) of the gradient surface.
/// Row-parallel over the `f` output rows with fixed-order f64
/// accumulation — bit-identical for any thread count, like
/// [`matmul_par`].
pub(crate) fn matmul_t_par(
    threads: Option<usize>,
    w: &[f32],
    g: &[f32],
    d: usize,
    f: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(w.len(), d * f);
    debug_assert_eq!(g.len(), d * m);
    debug_assert_eq!(out.len(), f * m);
    let ptr = SendPtr::new(out.as_mut_ptr());
    parallel_for_chunks_opt(threads, f, 16, |j0, j1| {
        ptr.claim(j0 * m, (j1 - j0) * m);
        for j in j0..j1 {
            // SAFETY: workers receive disjoint row ranges of `out`.
            let orow = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(j * m), m) };
            for (c, o) in orow.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for i in 0..d {
                    acc += w[i * f + j] as f64 * g[i * m + c] as f64;
                }
                *o = acc as f32;
            }
        }
    });
}

/// `out (d×m) += A (d×r) · (B (r×f) · X (f×m))` — the low-rank additive
/// update applied to activations without ever materializing `A·B`
/// (scratch is the `r×m` intermediate only). Fixed-order f64
/// accumulation, same determinism contract as [`matmul_acc_into`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn lora_activations_acc(
    a: &[f32],
    b: &[f32],
    x: &[f32],
    d: usize,
    r: usize,
    f: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), d * r);
    debug_assert_eq!(b.len(), r * f);
    debug_assert_eq!(x.len(), f * m);
    debug_assert_eq!(out.len(), d * m);
    let mut t = vec![0.0f64; r * m];
    for ti in 0..r {
        let brow = &b[ti * f..(ti + 1) * f];
        for c in 0..m {
            let mut acc = 0.0f64;
            for (j, &bv) in brow.iter().enumerate() {
                acc += bv as f64 * x[j * m + c] as f64;
            }
            t[ti * m + c] = acc;
        }
    }
    for i in 0..d {
        let arow = &a[i * r..(i + 1) * r];
        let orow = &mut out[i * m..(i + 1) * m];
        for (c, o) in orow.iter_mut().enumerate() {
            let mut acc = *o as f64;
            for (ti, &av) in arow.iter().enumerate() {
                acc += av as f64 * t[ti * m + c];
            }
            *o = acc as f32;
        }
    }
}

/// `out = w + a·b` (LoRA) over full slices: `a` is `d×r`, `b` is `r×f`.
pub(crate) fn lora_into(a: &[f32], b: &[f32], w: &[f32], d: usize, r: usize, f: usize, out: &mut [f32]) {
    debug_assert_eq!(w.len(), d * f);
    out.copy_from_slice(w);
    for i in 0..d {
        let orow = &mut out[i * f..(i + 1) * f];
        for t in 0..r {
            let av = a[i * r + t];
            if av == 0.0 {
                continue;
            }
            let brow = &b[t * f..(t + 1) * f];
            for (o, &x) in orow.iter_mut().zip(brow) {
                *o += av * x;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Blocked parallel drivers (the default public API).
// ---------------------------------------------------------------------------

/// Block-diagonal Householder reflection `H^B W` (paper Eq. 1 + §3.4),
/// blocked over column tiles and run on the scoped thread pool. Never
/// materializes H: per block it computes `W_i − 2 û_i (û_iᵀ W_i)`.
pub fn ether_apply(u: &[f32], n: usize, w: &Mat) -> Mat {
    let (d, f) = (w.rows, w.cols);
    assert_eq!(u.len(), d, "u blocks must tile the rows");
    assert!(n > 0 && d % n == 0, "n={n} must divide d={d}");
    let uh = normalize_blocks(u, n);
    let mut out = Mat::zeros(d, f);
    let ptr = SendPtr::new(out.data.as_mut_ptr());
    parallel_for_chunks(f, COL_TILE, |c0, c1| {
        ptr.claim_strided(c0, f, d, c1 - c0);
        // SAFETY: workers receive disjoint column ranges.
        unsafe { ether_cols(&uh, n, &w.data, f, ptr.get(), c0, c1) }
    });
    out
}

/// Left-side relaxed reflection `H⁺ W`, `H⁺ = I − ûûᵀ + v̂v̂ᵀ` (§3.3),
/// blocked over column tiles.
pub fn ether_plus_left(u: &[f32], v: &[f32], n: usize, w: &Mat) -> Mat {
    let (d, f) = (w.rows, w.cols);
    assert_eq!(u.len(), d, "u blocks must tile the rows");
    assert_eq!(v.len(), d, "v blocks must tile the rows");
    assert!(n > 0 && d % n == 0, "n={n} must divide d={d}");
    let uh = normalize_blocks(u, n);
    let vh = normalize_blocks(v, n);
    let mut out = Mat::zeros(d, f);
    let ptr = SendPtr::new(out.data.as_mut_ptr());
    parallel_for_chunks(f, COL_TILE, |c0, c1| {
        ptr.claim_strided(c0, f, d, c1 - c0);
        // SAFETY: workers receive disjoint column ranges.
        unsafe { ether_plus_left_cols(&uh, &vh, n, &w.data, f, ptr.get(), c0, c1) }
    });
    out
}

/// Right-side relaxed reflection `W H̃⁺` (columns blocked into n groups),
/// parallel over row chunks (the transform is row-local).
pub fn ether_plus_right(w: &Mat, u: &[f32], v: &[f32], n: usize) -> Mat {
    let (d, f) = (w.rows, w.cols);
    assert_eq!(u.len(), f, "u blocks must tile the columns");
    assert_eq!(v.len(), f, "v blocks must tile the columns");
    assert!(n > 0 && f % n == 0, "n={n} must divide f={f}");
    let uh = normalize_blocks(u, n);
    let vh = normalize_blocks(v, n);
    let mut out = w.clone();
    let ptr = SendPtr::new(out.data.as_mut_ptr());
    parallel_for_chunks(d, ROW_TILE, |r0, r1| {
        ptr.claim(r0 * f, (r1 - r0) * f);
        // SAFETY: workers receive disjoint row ranges of `out`.
        let rows =
            unsafe { std::slice::from_raw_parts_mut(ptr.get().add(r0 * f), (r1 - r0) * f) };
        ether_plus_right_rows(rows, f, &uh, &vh, n);
    });
    out
}

/// Apply block-diagonal multipliers: `Q^B W` (OFT / Naive compute path),
/// blocked over column tiles.
pub fn bdmm(blocks: &[Mat], w: &Mat) -> Mat {
    bdmm_scaled(blocks, w, None)
}

/// [`bdmm`] fused with the OFT magnitude-refit column scaling
/// `out[·, c] *= 1 + mag[c]` — one sweep instead of a multiply followed
/// by a per-row rescale pass.
pub fn bdmm_scaled(blocks: &[Mat], w: &Mat, scale: Option<&[f32]>) -> Mat {
    let n = blocks.len();
    let k = blocks[0].rows;
    assert_eq!(n * k, w.rows);
    let f = w.cols;
    if let Some(mag) = scale {
        assert_eq!(mag.len(), f, "magnitude vector must have one entry per column");
    }
    let mut out = Mat::zeros(w.rows, f);
    let ptr = SendPtr::new(out.data.as_mut_ptr());
    parallel_for_chunks(f, COL_TILE, |c0, c1| {
        ptr.claim_strided(c0, f, n * k, c1 - c0);
        // SAFETY: workers receive disjoint column ranges.
        unsafe { bdmm_cols(blocks, &w.data, f, scale, ptr.get(), c0, c1) }
    });
    out
}

// ---------------------------------------------------------------------------
// Serial scalar references (the pre-refactor implementations, kept as
// parity oracles and benchmark baselines).
// ---------------------------------------------------------------------------

/// Serial scalar reference for [`ether_apply`].
pub fn ether_apply_serial(u: &[f32], n: usize, w: &Mat) -> Mat {
    let d = w.rows;
    let db = d / n;
    assert_eq!(u.len(), d, "u blocks must tile the rows");
    let f = w.cols;
    let mut out = w.clone();
    for b in 0..n {
        let uh = normalize(&u[b * db..(b + 1) * db]);
        // proj = ûᵀ W_b  (f,)
        let mut proj = vec![0.0f64; f];
        for r in 0..db {
            let row = w.row(b * db + r);
            let uv = uh[r] as f64;
            for c in 0..f {
                proj[c] += uv * row[c] as f64;
            }
        }
        for r in 0..db {
            let uv = 2.0 * uh[r] as f64;
            let orow = out.row_mut(b * db + r);
            for c in 0..f {
                orow[c] -= (uv * proj[c]) as f32;
            }
        }
    }
    out
}

/// Serial scalar reference for [`ether_plus_left`].
pub fn ether_plus_left_serial(u: &[f32], v: &[f32], n: usize, w: &Mat) -> Mat {
    let d = w.rows;
    let db = d / n;
    let f = w.cols;
    let mut out = w.clone();
    for b in 0..n {
        let uh = normalize(&u[b * db..(b + 1) * db]);
        let vh = normalize(&v[b * db..(b + 1) * db]);
        let mut pu = vec![0.0f64; f];
        let mut pv = vec![0.0f64; f];
        for r in 0..db {
            let row = w.row(b * db + r);
            for c in 0..f {
                pu[c] += uh[r] as f64 * row[c] as f64;
                pv[c] += vh[r] as f64 * row[c] as f64;
            }
        }
        for r in 0..db {
            let orow = out.row_mut(b * db + r);
            for c in 0..f {
                orow[c] += (-(uh[r] as f64) * pu[c] + vh[r] as f64 * pv[c]) as f32;
            }
        }
    }
    out
}

/// Serial scalar reference for [`ether_plus_right`].
pub fn ether_plus_right_serial(w: &Mat, u: &[f32], v: &[f32], n: usize) -> Mat {
    let f = w.cols;
    let fb = f / n;
    let d = w.rows;
    let mut out = w.clone();
    for b in 0..n {
        let uh = normalize(&u[b * fb..(b + 1) * fb]);
        let vh = normalize(&v[b * fb..(b + 1) * fb]);
        for r in 0..d {
            let row = &w.row(r)[b * fb..(b + 1) * fb];
            let mut pu = 0.0f64;
            let mut pv = 0.0f64;
            for c in 0..fb {
                pu += row[c] as f64 * uh[c] as f64;
                pv += row[c] as f64 * vh[c] as f64;
            }
            let orow = &mut out.row_mut(r)[b * fb..(b + 1) * fb];
            for c in 0..fb {
                orow[c] += (-pu * uh[c] as f64 + pv * vh[c] as f64) as f32;
            }
        }
    }
    out
}

/// Serial scalar reference for [`bdmm`].
pub fn bdmm_serial(blocks: &[Mat], w: &Mat) -> Mat {
    let n = blocks.len();
    let k = blocks[0].rows;
    assert_eq!(n * k, w.rows);
    let f = w.cols;
    let mut out = Mat::zeros(w.rows, f);
    for (b, q) in blocks.iter().enumerate() {
        for i in 0..k {
            let orow = out.row_mut(b * k + i);
            for j in 0..k {
                let qv = q.at(i, j);
                if qv == 0.0 {
                    continue;
                }
                let wrow = w.row(b * k + j);
                for c in 0..f {
                    orow[c] += qv * wrow[c];
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Block constructors and dense materializations (unchanged).
// ---------------------------------------------------------------------------

/// Cayley map per block: R → Q = (I + S)(I − S)⁻¹, S = ½(R − Rᵀ) (OFT).
pub fn cayley_blocks(r: &[f32], n: usize, k: usize) -> Vec<Mat> {
    (0..n)
        .map(|b| {
            let blk = &r[b * k * k..(b + 1) * k * k];
            let mut s = Mat::zeros(k, k);
            for i in 0..k {
                for j in 0..k {
                    *s.at_mut(i, j) = 0.5 * (blk[i * k + j] - blk[j * k + i]);
                }
            }
            let ims = Mat::eye(k).sub(&s);
            let ips = Mat::eye(k).add(&s);
            let inv = solve::gauss_jordan_inv(&ims)
                .expect("I − S is always invertible for skew-symmetric S");
            ips.matmul(&inv)
        })
        .collect()
}

/// Unconstrained multiplicative blocks N = I + R (the paper's §5.3 Naive).
pub fn naive_blocks(r: &[f32], n: usize, k: usize) -> Vec<Mat> {
    (0..n)
        .map(|b| {
            let blk = &r[b * k * k..(b + 1) * k * k];
            let mut m = Mat::eye(k);
            for i in 0..k * k {
                m.data[i] += blk[i];
            }
            m
        })
        .collect()
}

/// LoRA additive update `W + A B` (A: d×r, B: r×f).
pub fn lora_apply(a: &Mat, b: &Mat, w: &Mat) -> Mat {
    w.add(&a.matmul(b))
}

/// Materialized block-diagonal `H^B` (analysis + tests only).
pub fn householder_dense(u: &[f32], n: usize) -> Mat {
    let d = u.len();
    let db = d / n;
    let mut h = Mat::eye(d);
    for b in 0..n {
        let uh = normalize(&u[b * db..(b + 1) * db]);
        for i in 0..db {
            for j in 0..db {
                *h.at_mut(b * db + i, b * db + j) -= 2.0 * uh[i] * uh[j];
            }
        }
    }
    h
}

/// Materialized block-diagonal `H⁺` (analysis + tests only).
pub fn ether_plus_dense(u: &[f32], v: &[f32], n: usize) -> Mat {
    let d = u.len();
    let db = d / n;
    let mut h = Mat::eye(d);
    for b in 0..n {
        let uh = normalize(&u[b * db..(b + 1) * db]);
        let vh = normalize(&v[b * db..(b + 1) * db]);
        for i in 0..db {
            for j in 0..db {
                *h.at_mut(b * db + i, b * db + j) += -uh[i] * uh[j] + vh[i] * vh[j];
            }
        }
    }
    h
}

/// Materialized block-diagonal matrix from dense blocks.
pub fn blockdiag_dense(blocks: &[Mat]) -> Mat {
    let k = blocks[0].rows;
    let d = k * blocks.len();
    let mut m = Mat::zeros(d, d);
    for (b, q) in blocks.iter().enumerate() {
        for i in 0..k {
            for j in 0..k {
                *m.at_mut(b * k + i, b * k + j) = q.at(i, j);
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ether_matches_dense() {
        let mut rng = Rng::new(0);
        let (d, f, n) = (24, 10, 4);
        let u = rng.normal_vec(d, 1.0);
        let w = Mat::randn(d, f, 1.0, &mut rng);
        let fast = ether_apply(&u, n, &w);
        let dense = householder_dense(&u, n).matmul(&w);
        assert!(fast.max_abs_diff(&dense) < 1e-5);
    }

    #[test]
    fn ether_preserves_norm() {
        // Orthogonality: ‖H^B W‖_F = ‖W‖_F.
        let mut rng = Rng::new(1);
        let u = rng.normal_vec(32, 1.0);
        let w = Mat::randn(32, 8, 1.0, &mut rng);
        let out = ether_apply(&u, 4, &w);
        assert!((out.fro() - w.fro()).abs() < 1e-4);
    }

    #[test]
    fn ether_plus_identity_when_u_eq_v() {
        let mut rng = Rng::new(2);
        let u = rng.normal_vec(16, 1.0);
        let w = Mat::randn(16, 6, 1.0, &mut rng);
        let out = ether_plus_left(&u, &u, 2, &w);
        assert!(out.max_abs_diff(&w) < 1e-6);
        let ru = rng.normal_vec(6, 1.0);
        let out2 = ether_plus_right(&w, &ru, &ru, 1);
        assert!(out2.max_abs_diff(&w) < 1e-6);
    }

    #[test]
    fn ether_plus_matches_dense() {
        let mut rng = Rng::new(3);
        let (d, f, n) = (16, 12, 2);
        let u = rng.normal_vec(d, 1.0);
        let v = rng.normal_vec(d, 1.0);
        let w = Mat::randn(d, f, 1.0, &mut rng);
        let fast = ether_plus_left(&u, &v, n, &w);
        let dense = ether_plus_dense(&u, &v, n).matmul(&w);
        assert!(fast.max_abs_diff(&dense) < 1e-5);
        // right side: W H̃ == (H̃ᵀ Wᵀ)ᵀ and H̃ symmetric
        let ru = rng.normal_vec(f, 1.0);
        let rv = rng.normal_vec(f, 1.0);
        let fast_r = ether_plus_right(&w, &ru, &rv, n);
        let dense_r = w.matmul(&ether_plus_dense(&ru, &rv, n));
        assert!(fast_r.max_abs_diff(&dense_r) < 1e-5);
    }

    #[test]
    fn blocked_engine_matches_serial_reference() {
        // Odd shapes on purpose: f smaller than, equal to, and far above
        // the column tile, so every chunking path is exercised.
        let mut rng = Rng::new(7);
        for &(d, f, n) in &[(24usize, 10usize, 4usize), (32, 64, 2), (48, 200, 3), (16, 1, 1)] {
            let w = Mat::randn(d, f, 1.0, &mut rng);
            let u = rng.normal_vec(d, 1.0);
            let v = rng.normal_vec(d, 1.0);
            let fast = ether_apply(&u, n, &w);
            let slow = ether_apply_serial(&u, n, &w);
            assert!(fast.max_abs_diff(&slow) < 1e-5, "ether d={d} f={f} n={n}");
            let fast = ether_plus_left(&u, &v, n, &w);
            let slow = ether_plus_left_serial(&u, &v, n, &w);
            assert!(fast.max_abs_diff(&slow) < 1e-5, "ether+ left d={d} f={f} n={n}");
        }
        // Right side + bdmm on column-block-compatible shapes.
        let w = Mat::randn(24, 12, 1.0, &mut rng);
        let ru = rng.normal_vec(12, 1.0);
        let rv = rng.normal_vec(12, 1.0);
        let fast = ether_plus_right(&w, &ru, &rv, 3);
        let slow = ether_plus_right_serial(&w, &ru, &rv, 3);
        assert!(fast.max_abs_diff(&slow) < 1e-5);
        let blocks: Vec<Mat> = (0..3).map(|_| Mat::randn(8, 8, 1.0, &mut rng)).collect();
        let w = Mat::randn(24, 100, 1.0, &mut rng);
        assert!(bdmm(&blocks, &w).max_abs_diff(&bdmm_serial(&blocks, &w)) < 1e-5);
    }

    #[test]
    fn bdmm_scaled_fuses_magnitude_refit() {
        let mut rng = Rng::new(8);
        let (n, k, f) = (2usize, 4usize, 9usize);
        let blocks: Vec<Mat> = (0..n).map(|_| Mat::randn(k, k, 1.0, &mut rng)).collect();
        let w = Mat::randn(n * k, f, 1.0, &mut rng);
        let mag = rng.normal_vec(f, 0.1);
        let fused = bdmm_scaled(&blocks, &w, Some(&mag));
        // reference: multiply, then scale columns
        let mut two_pass = bdmm_serial(&blocks, &w);
        for r in 0..n * k {
            let row = two_pass.row_mut(r);
            for c in 0..f {
                row[c] *= 1.0 + mag[c];
            }
        }
        assert!(fused.max_abs_diff(&two_pass) < 1e-5);
    }

    #[test]
    fn cayley_blocks_are_orthogonal_det_plus_one() {
        let mut rng = Rng::new(4);
        let (n, k) = (3, 6);
        let r = rng.normal_vec(n * k * k, 1.0);
        for q in cayley_blocks(&r, n, k) {
            let qqt = q.matmul(&q.transpose());
            assert!(qqt.max_abs_diff(&Mat::eye(k)) < 1e-4);
            assert!((solve::det(&q) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn householder_det_minus_one() {
        // The determinant gap of §3.2: Cayley gives +1, Householder −1.
        let mut rng = Rng::new(5);
        let u = rng.normal_vec(8, 1.0);
        let h = householder_dense(&u, 1);
        assert!((solve::det(&h) + 1.0).abs() < 1e-4);
    }

    #[test]
    fn bdmm_matches_dense() {
        let mut rng = Rng::new(6);
        let (n, k, f) = (2, 4, 5);
        let blocks: Vec<Mat> = (0..n).map(|_| Mat::randn(k, k, 1.0, &mut rng)).collect();
        let w = Mat::randn(n * k, f, 1.0, &mut rng);
        let fast = bdmm(&blocks, &w);
        let dense = blockdiag_dense(&blocks).matmul(&w);
        assert!(fast.max_abs_diff(&dense) < 1e-5);
    }

    #[test]
    #[should_panic(expected = "do not tile")]
    fn normalize_blocks_rejects_non_tiling_input_in_release_too() {
        let _ = normalize_blocks(&[1.0; 10], 3);
    }

    #[test]
    fn matmul_par_matches_serial_and_is_thread_invariant() {
        let mut rng = Rng::new(17);
        let (d, f, m) = (37usize, 23usize, 5usize);
        let w: Vec<f32> = rng.normal_vec(d * f, 0.5);
        let x: Vec<f32> = rng.normal_vec(f * m, 0.5);
        let mut serial = vec![0.0f32; d * m];
        matmul_acc_into(&w, &x, d, f, m, &mut serial);
        for threads in [Some(1), Some(4), None] {
            let mut out = vec![0.0f32; d * m];
            matmul_par(threads, &w, &x, d, f, m, &mut out);
            assert!(
                out.iter().zip(&serial).all(|(a, b)| a.to_bits() == b.to_bits()),
                "matmul_par bits differ at threads={threads:?}"
            );
        }
        // Transpose kernel against a dense reference.
        let g: Vec<f32> = rng.normal_vec(d * m, 0.5);
        let wm = Mat::from_vec(d, f, w.clone());
        let gm = Mat::from_vec(d, m, g.clone());
        let dense = wm.transpose().matmul(&gm);
        for threads in [Some(1), Some(4), None] {
            let mut out = vec![0.0f32; f * m];
            matmul_t_par(threads, &w, &g, d, f, m, &mut out);
            let err = out
                .iter()
                .zip(&dense.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 1e-5, "matmul_t_par vs dense {err} (threads={threads:?})");
        }
    }

    #[test]
    fn naive_blocks_identity_at_zero() {
        let r = vec![0.0; 2 * 9];
        for b in naive_blocks(&r, 2, 3) {
            assert!(b.max_abs_diff(&Mat::eye(3)) < 1e-9);
        }
    }
}
