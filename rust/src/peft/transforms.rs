//! Host-tensor implementations of every weight transform in the family.
//!
//! Math mirrors the Layer-1 Pallas kernels exactly (same guarded
//! normalization, same block semantics); see `python/compile/kernels/`.

use crate::tensor::{solve, Mat};

/// Guard used by the kernels' in-place normalization (must match
/// `kernels/ether.py::NORM_EPS`).
pub const NORM_EPS: f64 = 1e-12;

/// û = u · rsqrt(Σu² + ε).
pub fn normalize(u: &[f32]) -> Vec<f32> {
    let s: f64 = u.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let r = 1.0 / (s + NORM_EPS).sqrt();
    u.iter().map(|&x| (x as f64 * r) as f32).collect()
}

/// Block-diagonal Householder reflection `H^B W` (paper Eq. 1 + §3.4).
///
/// `u` is the flattened (n, d/n) block of raw hyperplane normals. Never
/// materializes H: per block it computes `W_i − 2 û_i (û_iᵀ W_i)`.
pub fn ether_apply(u: &[f32], n: usize, w: &Mat) -> Mat {
    let d = w.rows;
    let db = d / n;
    assert_eq!(u.len(), d, "u blocks must tile the rows");
    let f = w.cols;
    let mut out = w.clone();
    for b in 0..n {
        let uh = normalize(&u[b * db..(b + 1) * db]);
        // proj = ûᵀ W_b  (f,)
        let mut proj = vec![0.0f64; f];
        for r in 0..db {
            let row = w.row(b * db + r);
            let uv = uh[r] as f64;
            for c in 0..f {
                proj[c] += uv * row[c] as f64;
            }
        }
        for r in 0..db {
            let uv = 2.0 * uh[r] as f64;
            let orow = out.row_mut(b * db + r);
            for c in 0..f {
                orow[c] -= (uv * proj[c]) as f32;
            }
        }
    }
    out
}

/// Left-side relaxed reflection `H⁺ W`, `H⁺ = I − ûûᵀ + v̂v̂ᵀ` (§3.3).
pub fn ether_plus_left(u: &[f32], v: &[f32], n: usize, w: &Mat) -> Mat {
    let d = w.rows;
    let db = d / n;
    let f = w.cols;
    let mut out = w.clone();
    for b in 0..n {
        let uh = normalize(&u[b * db..(b + 1) * db]);
        let vh = normalize(&v[b * db..(b + 1) * db]);
        let mut pu = vec![0.0f64; f];
        let mut pv = vec![0.0f64; f];
        for r in 0..db {
            let row = w.row(b * db + r);
            for c in 0..f {
                pu[c] += uh[r] as f64 * row[c] as f64;
                pv[c] += vh[r] as f64 * row[c] as f64;
            }
        }
        for r in 0..db {
            let orow = out.row_mut(b * db + r);
            for c in 0..f {
                orow[c] += (-(uh[r] as f64) * pu[c] + vh[r] as f64 * pv[c]) as f32;
            }
        }
    }
    out
}

/// Right-side relaxed reflection `W H̃⁺` (columns blocked into n groups).
pub fn ether_plus_right(w: &Mat, u: &[f32], v: &[f32], n: usize) -> Mat {
    let f = w.cols;
    let fb = f / n;
    let d = w.rows;
    let mut out = w.clone();
    for b in 0..n {
        let uh = normalize(&u[b * fb..(b + 1) * fb]);
        let vh = normalize(&v[b * fb..(b + 1) * fb]);
        for r in 0..d {
            let row = &w.row(r)[b * fb..(b + 1) * fb];
            let mut pu = 0.0f64;
            let mut pv = 0.0f64;
            for c in 0..fb {
                pu += row[c] as f64 * uh[c] as f64;
                pv += row[c] as f64 * vh[c] as f64;
            }
            let orow = &mut out.row_mut(r)[b * fb..(b + 1) * fb];
            for c in 0..fb {
                orow[c] += (-pu * uh[c] as f64 + pv * vh[c] as f64) as f32;
            }
        }
    }
    out
}

/// Cayley map per block: R → Q = (I + S)(I − S)⁻¹, S = ½(R − Rᵀ) (OFT).
pub fn cayley_blocks(r: &[f32], n: usize, k: usize) -> Vec<Mat> {
    (0..n)
        .map(|b| {
            let blk = &r[b * k * k..(b + 1) * k * k];
            let mut s = Mat::zeros(k, k);
            for i in 0..k {
                for j in 0..k {
                    *s.at_mut(i, j) = 0.5 * (blk[i * k + j] - blk[j * k + i]);
                }
            }
            let ims = Mat::eye(k).sub(&s);
            let ips = Mat::eye(k).add(&s);
            let inv = solve::gauss_jordan_inv(&ims)
                .expect("I − S is always invertible for skew-symmetric S");
            ips.matmul(&inv)
        })
        .collect()
}

/// Unconstrained multiplicative blocks N = I + R (the paper's §5.3 Naive).
pub fn naive_blocks(r: &[f32], n: usize, k: usize) -> Vec<Mat> {
    (0..n)
        .map(|b| {
            let blk = &r[b * k * k..(b + 1) * k * k];
            let mut m = Mat::eye(k);
            for i in 0..k * k {
                m.data[i] += blk[i];
            }
            m
        })
        .collect()
}

/// Apply block-diagonal multipliers: `Q^B W` (OFT / Naive compute path).
pub fn bdmm(blocks: &[Mat], w: &Mat) -> Mat {
    let n = blocks.len();
    let k = blocks[0].rows;
    assert_eq!(n * k, w.rows);
    let f = w.cols;
    let mut out = Mat::zeros(w.rows, f);
    for (b, q) in blocks.iter().enumerate() {
        for i in 0..k {
            let orow = out.row_mut(b * k + i);
            for j in 0..k {
                let qv = q.at(i, j);
                if qv == 0.0 {
                    continue;
                }
                let wrow = w.row(b * k + j);
                for c in 0..f {
                    orow[c] += qv * wrow[c];
                }
            }
        }
    }
    out
}

/// LoRA additive update `W + A B` (A: d×r, B: r×f).
pub fn lora_apply(a: &Mat, b: &Mat, w: &Mat) -> Mat {
    w.add(&a.matmul(b))
}

/// Materialized block-diagonal `H^B` (analysis + tests only).
pub fn householder_dense(u: &[f32], n: usize) -> Mat {
    let d = u.len();
    let db = d / n;
    let mut h = Mat::eye(d);
    for b in 0..n {
        let uh = normalize(&u[b * db..(b + 1) * db]);
        for i in 0..db {
            for j in 0..db {
                *h.at_mut(b * db + i, b * db + j) -= 2.0 * uh[i] * uh[j];
            }
        }
    }
    h
}

/// Materialized block-diagonal `H⁺` (analysis + tests only).
pub fn ether_plus_dense(u: &[f32], v: &[f32], n: usize) -> Mat {
    let d = u.len();
    let db = d / n;
    let mut h = Mat::eye(d);
    for b in 0..n {
        let uh = normalize(&u[b * db..(b + 1) * db]);
        let vh = normalize(&v[b * db..(b + 1) * db]);
        for i in 0..db {
            for j in 0..db {
                *h.at_mut(b * db + i, b * db + j) += -uh[i] * uh[j] + vh[i] * vh[j];
            }
        }
    }
    h
}

/// Materialized block-diagonal matrix from dense blocks.
pub fn blockdiag_dense(blocks: &[Mat]) -> Mat {
    let k = blocks[0].rows;
    let d = k * blocks.len();
    let mut m = Mat::zeros(d, d);
    for (b, q) in blocks.iter().enumerate() {
        for i in 0..k {
            for j in 0..k {
                *m.at_mut(b * k + i, b * k + j) = q.at(i, j);
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ether_matches_dense() {
        let mut rng = Rng::new(0);
        let (d, f, n) = (24, 10, 4);
        let u = rng.normal_vec(d, 1.0);
        let w = Mat::randn(d, f, 1.0, &mut rng);
        let fast = ether_apply(&u, n, &w);
        let dense = householder_dense(&u, n).matmul(&w);
        assert!(fast.max_abs_diff(&dense) < 1e-5);
    }

    #[test]
    fn ether_preserves_norm() {
        // Orthogonality: ‖H^B W‖_F = ‖W‖_F.
        let mut rng = Rng::new(1);
        let u = rng.normal_vec(32, 1.0);
        let w = Mat::randn(32, 8, 1.0, &mut rng);
        let out = ether_apply(&u, 4, &w);
        assert!((out.fro() - w.fro()).abs() < 1e-4);
    }

    #[test]
    fn ether_plus_identity_when_u_eq_v() {
        let mut rng = Rng::new(2);
        let u = rng.normal_vec(16, 1.0);
        let w = Mat::randn(16, 6, 1.0, &mut rng);
        let out = ether_plus_left(&u, &u, 2, &w);
        assert!(out.max_abs_diff(&w) < 1e-6);
        let ru = rng.normal_vec(6, 1.0);
        let out2 = ether_plus_right(&w, &ru, &ru, 1);
        assert!(out2.max_abs_diff(&w) < 1e-6);
    }

    #[test]
    fn ether_plus_matches_dense() {
        let mut rng = Rng::new(3);
        let (d, f, n) = (16, 12, 2);
        let u = rng.normal_vec(d, 1.0);
        let v = rng.normal_vec(d, 1.0);
        let w = Mat::randn(d, f, 1.0, &mut rng);
        let fast = ether_plus_left(&u, &v, n, &w);
        let dense = ether_plus_dense(&u, &v, n).matmul(&w);
        assert!(fast.max_abs_diff(&dense) < 1e-5);
        // right side: W H̃ == (H̃ᵀ Wᵀ)ᵀ and H̃ symmetric
        let ru = rng.normal_vec(f, 1.0);
        let rv = rng.normal_vec(f, 1.0);
        let fast_r = ether_plus_right(&w, &ru, &rv, n);
        let dense_r = w.matmul(&ether_plus_dense(&ru, &rv, n));
        assert!(fast_r.max_abs_diff(&dense_r) < 1e-5);
    }

    #[test]
    fn cayley_blocks_are_orthogonal_det_plus_one() {
        let mut rng = Rng::new(4);
        let (n, k) = (3, 6);
        let r = rng.normal_vec(n * k * k, 1.0);
        for q in cayley_blocks(&r, n, k) {
            let qqt = q.matmul(&q.transpose());
            assert!(qqt.max_abs_diff(&Mat::eye(k)) < 1e-4);
            assert!((solve::det(&q) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn householder_det_minus_one() {
        // The determinant gap of §3.2: Cayley gives +1, Householder −1.
        let mut rng = Rng::new(5);
        let u = rng.normal_vec(8, 1.0);
        let h = householder_dense(&u, 1);
        assert!((solve::det(&h) + 1.0).abs() < 1e-4);
    }

    #[test]
    fn bdmm_matches_dense() {
        let mut rng = Rng::new(6);
        let (n, k, f) = (2, 4, 5);
        let blocks: Vec<Mat> = (0..n).map(|_| Mat::randn(k, k, 1.0, &mut rng)).collect();
        let w = Mat::randn(n * k, f, 1.0, &mut rng);
        let fast = bdmm(&blocks, &w);
        let dense = blockdiag_dense(&blocks).matmul(&w);
        assert!(fast.max_abs_diff(&dense) < 1e-5);
    }

    #[test]
    fn naive_blocks_identity_at_zero() {
        let r = vec![0.0; 2 * 9];
        for b in naive_blocks(&r, 2, 3) {
            assert!(b.max_abs_diff(&Mat::eye(3)) < 1e-9);
        }
    }
}
