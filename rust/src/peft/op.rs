//! The [`TransformOp`] trait: one object per PEFT family member.
//!
//! Every method in the family — ETHER's hyperplane reflections (paper
//! Eq. 1), the relaxed ETHER+ (§3.3), OFT's Cayley blocks, the §5.3
//! Naive control, LoRA/VeRA/DeLoRA-style additive updates, full
//! finetuning and the `none` identity — is described by a single trait
//! implementation instead of `match spec.kind` arms scattered across the
//! crate. The trait contract:
//!
//! * [`TransformOp::param_schema`] is the **single source of truth** for
//!   a method's per-layer parameter fields. `peft::apply::peft_layout_for`
//!   (flat [`crate::peft::flat::Layout`] construction),
//!   `peft::count_params`, manifest cross-validation, and per-item view
//!   resolution are all derived from it — adding a field in one place
//!   propagates everywhere.
//! * [`TransformOp::apply_blocked`] transforms one weight matrix with the
//!   blocked parallel column-tile engine (analysis drivers).
//! * [`TransformOp::apply_into`] is the single-threaded slice kernel a
//!   `MergePlan` work item runs, writing straight into the merged buffer.
//! * [`TransformOp::apply_serial`] is the scalar parity oracle.
//! * [`TransformOp::unmerge_into`] (optional) inverts the transform on a
//!   merged slice. ETHER's reflection is its own inverse (H·H = I,
//!   §3.2); ETHER+ inverts through the rank-2 Woodbury identity; OFT
//!   through the orthogonal transpose; Naive through a block inverse;
//!   LoRA/DeLoRA by subtracting the additive update. The serving layer's
//!   in-place adapter swap is built on this hook.
//! * [`TransformOp::apply_activations_into`] (optional, gated by
//!   [`TransformOp::supports_activations`]) applies the transform
//!   **directly to activations**: `out = T(W)·x` without ever
//!   materializing the merged `d×f` matrix. A rank-1 reflection costs
//!   O(d) per column on top of the base product, so the serving layer's
//!   `OnTheFly` execution strategy can serve the cold adapter long tail
//!   at zero merged-buffer memory. [`TransformOp::apply_activations_serial`]
//!   is the oracle (materialize, then multiply).
//! * [`TransformOp::grad_params_into`] (optional, gated by
//!   [`TransformOp::supports_grad`]) is the **training surface**:
//!   accumulate `∂L/∂θ` through the merged transform's activation
//!   forward, given `upstream = ∂L/∂y`. ETHER differentiates through
//!   the Householder product rule (the training loop re-normalizes each
//!   reflection vector after the step, as the paper prescribes), ETHER+
//!   through the rank-2 relaxation, OFT through the Cayley map, and the
//!   additive members through plain product rules. Kernels are
//!   blocked-parallel over disjoint gradient regions with fixed-order
//!   f64 reductions — bit-identical for any thread count — and are
//!   verified against central finite differences by
//!   `rust/tests/grad_props.rs`. [`TransformOp::grad_params_serial`] is
//!   the pinned-serial oracle.
//!
//! To add a new method: implement the trait on a unit struct here, add
//! the [`crate::peft::MethodKind`] variant, and register it in
//! [`crate::peft::registry::op_for`]. Nothing else in the crate changes —
//! [`DeloraOp`] (DeLoRA-style normalized low-rank with a decoupled
//! strength scalar) is the worked example.

use anyhow::{anyhow, bail, ensure, Result};

use crate::peft::flat::Layout;
use crate::peft::transforms as tf;
use crate::peft::{MethodKind, MethodSpec};
use crate::tensor::{solve, Mat};
use crate::util::pool::{parallel_for_chunks_opt, SendPtr};

/// How a method's numeric name suffix parameterizes it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arity {
    /// `<token>_n<num>` sets `n_blocks` (ether, etherplus, oft, naive).
    Blocks,
    /// `<token>_r<num>` sets `rank` (lora, vera, delora).
    Rank,
    /// No numeric suffix (full, none).
    Fixed,
}

/// Parameter views for one (matrix, layer) pair, resolved against the
/// op's schema by [`resolve_params`] — every field is present with the
/// exact schema size, so op kernels read them infallibly via
/// [`ResolvedParams::get`].
pub struct ResolvedParams<'a> {
    fields: Vec<(&'static str, &'a [f32])>,
}

impl<'a> ResolvedParams<'a> {
    /// Fetch a schema field. Panics on a field the schema does not
    /// declare — that is a programming error in the op, not bad data
    /// (data errors are caught in [`resolve_params`]).
    pub fn get(&self, field: &str) -> &'a [f32] {
        self.fields
            .iter()
            .find(|(name, _)| *name == field)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("op requested field {field:?} missing from its own schema"))
    }
}

/// Resolve an op's schema fields for adapted matrix `mat` (shape `d×f`),
/// layer `layer`, against a flat PEFT vector. Validates the spec for
/// this shape and every field's length, so downstream kernels cannot
/// silently part-transform (or panic on a worker thread) on a layout
/// inconsistent with the model dims.
pub fn resolve_params<'a>(
    op: &dyn TransformOp,
    spec: &MethodSpec,
    peft: &'a [f32],
    layout: &Layout,
    mat: &str,
    layer: usize,
    d: usize,
    f: usize,
) -> Result<ResolvedParams<'a>> {
    op.validate(spec, mat, d, f)?;
    let mut fields = Vec::new();
    for (field, shape) in op.param_schema(spec, d, f) {
        let want: usize = shape.iter().product();
        let v = layout.view_layer(peft, &format!("{mat}.{field}"), layer)?;
        ensure!(
            v.len() == want,
            "{mat}[{layer}].{field}: length {} != {want} expected by the {} schema",
            v.len(),
            op.token()
        );
        fields.push((field, v));
    }
    Ok(ResolvedParams { fields })
}

/// Mutable parameter-gradient views for one (matrix, layer) pair: the
/// same schema fields as [`ResolvedParams`], borrowed from a flat
/// gradient vector laid out exactly like the PEFT parameter vector.
/// Gradient kernels **accumulate** (`+=`) into these views, so callers
/// can sum contributions over work items and batches into one buffer
/// (zero it first for a plain gradient).
pub struct GradParams<'a> {
    fields: Vec<(&'static str, &'a mut [f32])>,
}

impl<'a> GradParams<'a> {
    /// Assemble from pre-carved field slices. The plan-level gradient
    /// sweep builds these from disjoint layout regions; [`resolve_grad`]
    /// is the checked constructor for everyone else. Slice lengths must
    /// match the op's schema exactly.
    pub fn from_fields(fields: Vec<(&'static str, &'a mut [f32])>) -> GradParams<'a> {
        GradParams { fields }
    }

    /// Fetch a schema field's gradient view. Panics on a field the
    /// schema does not declare — a programming error in the op, exactly
    /// like [`ResolvedParams::get`].
    pub fn get(&mut self, field: &str) -> &mut [f32] {
        self.fields
            .iter_mut()
            .find(|(name, _)| *name == field)
            .map(|(_, v)| &mut **v)
            .unwrap_or_else(|| panic!("op requested grad field {field:?} missing from its own schema"))
    }

    /// Fetch two distinct fields at once (for kernels that write both
    /// sides of a coupled update, e.g. the relaxed reflection's û/v̂).
    pub fn get2(&mut self, a: &str, b: &str) -> (&mut [f32], &mut [f32]) {
        let ia = self.index_of(a);
        let ib = self.index_of(b);
        assert_ne!(ia, ib, "get2 needs two distinct fields");
        let (lo, hi) = (ia.min(ib), ia.max(ib));
        let (head, tail) = self.fields.split_at_mut(hi);
        let first = &mut *head[lo].1;
        let second = &mut *tail[0].1;
        if ia < ib {
            (first, second)
        } else {
            (second, first)
        }
    }

    fn index_of(&self, field: &str) -> usize {
        self.fields
            .iter()
            .position(|(name, _)| *name == field)
            .unwrap_or_else(|| panic!("op requested grad field {field:?} missing from its own schema"))
    }
}

/// Resolved `(field, flat offset, length)` locations of an op's schema
/// fields for one (matrix, layer) pair in a flat PEFT-layout vector —
/// the single source of field placement shared by [`resolve_grad`] and
/// the plan-level gradient sweep
/// ([`crate::peft::apply::MergePlan::execute_grad_activations`]).
pub fn grad_field_locs(
    op: &dyn TransformOp,
    spec: &MethodSpec,
    layout: &Layout,
    mat: &str,
    layer: usize,
    d: usize,
    f: usize,
) -> Result<Vec<(&'static str, usize, usize)>> {
    let mut locs = Vec::new();
    for (field, shape) in op.param_schema(spec, d, f) {
        let want: usize = shape.iter().product();
        let e = layout.entry(&format!("{mat}.{field}"))?;
        let layers = e.shape[0];
        ensure!(layer < layers, "{mat}.{field}: layer {layer} out of range");
        let per = e.size / layers;
        ensure!(
            per == want,
            "{mat}[{layer}].{field}: length {per} != {want} expected by the {} schema",
            op.token()
        );
        locs.push((field, e.offset + layer * per, want));
    }
    Ok(locs)
}

/// Resolve an op's mutable gradient views for adapted matrix `mat`
/// (shape `d×f`), layer `layer`, against a flat gradient vector laid
/// out like the PEFT vector. The mutable companion of
/// [`resolve_params`]: validates the spec and every field's location,
/// then carves disjoint `&mut` slices out of `grad`.
#[allow(clippy::too_many_arguments)]
pub fn resolve_grad<'a>(
    op: &dyn TransformOp,
    spec: &MethodSpec,
    grad: &'a mut [f32],
    layout: &Layout,
    mat: &str,
    layer: usize,
    d: usize,
    f: usize,
) -> Result<GradParams<'a>> {
    op.validate(spec, mat, d, f)?;
    ensure!(
        grad.len() == layout.total,
        "gradient vector length {} != layout total {}",
        grad.len(),
        layout.total
    );
    let mut locs = grad_field_locs(op, spec, layout, mat, layer, d, f)?;
    locs.sort_unstable_by_key(|&(_, off, _)| off);
    let mut fields = Vec::with_capacity(locs.len());
    let mut rest: &'a mut [f32] = grad;
    let mut consumed = 0usize;
    for (field, off, len) in locs {
        ensure!(off >= consumed, "overlapping gradient fields for {mat}[{layer}]");
        let r = std::mem::take(&mut rest);
        let (_, tail) = r.split_at_mut(off - consumed);
        let (slice, tail) = tail.split_at_mut(len);
        fields.push((field, slice));
        rest = tail;
        consumed = off + len;
    }
    Ok(GradParams { fields })
}

/// Shape of one activation batch for the merge-free execution path
/// ([`TransformOp::apply_activations_into`]): the input `x` holds `m`
/// `f`-dimensional columns (`f×m`, row-major) and the output holds `m`
/// `d`-dimensional columns (`d×m`).
#[derive(Clone, Copy, Debug)]
pub struct ActShape {
    pub d: usize,
    pub f: usize,
    pub m: usize,
}

/// One member of the PEFT transform family (object-safe).
pub trait TransformOp: Sync + Send {
    /// The enum variant this op implements.
    fn kind(&self) -> MethodKind;

    /// Canonical name token (`"ether"`, `"lora"`, …) — also the full
    /// method name for [`Arity::Fixed`] ops.
    fn token(&self) -> &'static str;

    /// How the numeric suffix of the method name is interpreted.
    fn arity(&self) -> Arity;

    /// Render the canonical method name for a spec of this kind.
    fn spec_name(&self, spec: &MethodSpec) -> String;

    /// Multiplicative methods transform W by matrix multiplication; the
    /// paper's §5.3 control study hinges on this split.
    fn is_multiplicative(&self) -> bool {
        false
    }

    /// True only for the `none` op (merge is a pass-through copy).
    fn is_identity(&self) -> bool {
        false
    }

    /// Whether the host can merge this method (VeRA cannot: its frozen
    /// projections are jax-seeded HLO constants).
    fn host_mergeable(&self) -> bool {
        true
    }

    /// Whether [`TransformOp::unmerge_into`] is implemented.
    fn supports_unmerge(&self) -> bool {
        false
    }

    /// Per-layer parameter fields for one adapted `d×f` matrix:
    /// `(field, shape)` pairs in flat-vector order. The single source of
    /// truth for layout construction, parameter counting and validation.
    fn param_schema(&self, spec: &MethodSpec, d: usize, f: usize) -> Vec<(&'static str, Vec<usize>)>;

    /// Validate the spec against a `d×f` matrix before any kernel runs.
    /// Default: multiplicative ops require `n_blocks` to divide the rows.
    fn validate(&self, spec: &MethodSpec, mat: &str, d: usize, f: usize) -> Result<()> {
        let _ = f;
        if self.is_multiplicative() {
            ensure!(
                spec.n_blocks > 0 && d % spec.n_blocks == 0,
                "{mat}: n_blocks={} must divide rows {d}",
                spec.n_blocks
            );
        }
        Ok(())
    }

    /// Transform one matrix with the blocked parallel engine.
    fn apply_blocked(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat>;

    /// Serial scalar reference (parity oracle for `apply_blocked`).
    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat>;

    /// Single-threaded slice kernel for one `MergePlan` work item:
    /// transform the `d×f` slice `src` into `out`. Infallible by
    /// construction — params were resolved and validated up front.
    fn apply_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        src: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    );

    /// Inverse slice kernel: recover the pre-merge `d×f` slice from
    /// `merged`. Errors on numerically non-invertible parameters.
    fn unmerge_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        merged: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let _ = (spec, p, merged, d, f, out);
        bail!("{} does not support unmerge", self.token())
    }

    /// Whether [`TransformOp::apply_activations_into`] is implemented.
    /// The serving layer's on-the-fly (merge-free) execution strategy
    /// gates on this; every host-mergeable family member supports it.
    fn supports_activations(&self) -> bool {
        false
    }

    /// Merge-free adapted forward on activations: `out = T(W)·x` for one
    /// `d×f` base slice `w` and `m` input columns `x` (`f×m`), without
    /// ever materializing the merged `d×f` matrix — scratch stays
    /// activation-sized (`O((d+f)·m)`). This is the structural shortcut
    /// the paper's reflections make cheap: `H·y = y − 2û(ûᵀy)` costs
    /// `O(d)` per column on top of the base product, vs. the `O(d·f)`
    /// merged buffer the cached strategies keep resident.
    fn apply_activations_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let _ = (spec, p, w, x, shape, out);
        bail!("{} does not support activation application", self.token())
    }

    /// Allocating convenience over [`TransformOp::apply_activations_into`].
    fn apply_activations(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
    ) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; shape.d * shape.m];
        self.apply_activations_into(spec, p, w, x, shape, &mut out)?;
        Ok(out)
    }

    /// Serial oracle for the activation path: materialize the merged
    /// slice with [`TransformOp::apply_into`] and multiply — exactly the
    /// buffer the fast path avoids. Parity (≤ 1e-5) across the registry
    /// is locked in by `rust/tests/engine_parity.rs`.
    fn apply_activations_serial(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
    ) -> Result<Vec<f32>> {
        ensure!(
            self.host_mergeable(),
            "host merge unsupported for {} (no activation oracle)",
            self.token()
        );
        let mut merged = vec![0.0f32; shape.d * shape.f];
        self.apply_into(spec, p, w, shape.d, shape.f, &mut merged);
        let mut out = vec![0.0f32; shape.d * shape.m];
        tf::matmul_acc_into(&merged, x, shape.d, shape.f, shape.m, &mut out);
        Ok(out)
    }

    /// Whether [`TransformOp::grad_params_into`] is implemented. The
    /// host-native training engine ([`crate::train::host`]) gates on
    /// this; the differentiable family is pinned from the outside by
    /// `rust/tests/grad_props.rs`, the way `engine_parity.rs` pins the
    /// host-mergeable family.
    fn supports_grad(&self) -> bool {
        false
    }

    /// Accumulate `∂L/∂θ` into `grad` for one `d×f` work item, where
    /// the loss reaches this op's parameters θ through the merged
    /// transform's activation forward `y = T(W)·x` and
    /// `upstream = ∂L/∂y` (`d×m`, the activation-output shape).
    /// Kernels **accumulate** (`+=`) so callers can sum over items and
    /// batches.
    ///
    /// Implementations re-derive the forward intermediates they need
    /// (`z = W·x`, …) — the backward API is stateless. Every reduction
    /// runs in f64 in a fixed order and the blocked parallelism only
    /// splits **disjoint gradient regions** (blocks, rows, rank
    /// components), so results are **bit-identical for any thread
    /// count** (`threads: None` = ambient pool, `Some(1)` = pinned
    /// serial). Verified against central finite differences by
    /// `rust/tests/grad_props.rs`.
    #[allow(clippy::too_many_arguments)]
    fn grad_params_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        threads: Option<usize>,
        grad: &mut GradParams,
    ) -> Result<()> {
        let _ = (spec, p, w, x, upstream, shape, threads, grad);
        bail!("{} does not support parameter gradients", self.token())
    }

    /// Scalar serial oracle for [`TransformOp::grad_params_into`]: the
    /// same fixed-order kernels pinned to one worker (mirroring
    /// [`crate::peft::apply::MergePlan::execute_serial`]) — the blocked
    /// engine must reproduce its bits exactly, and central finite
    /// differences are the independent correctness oracle on top.
    #[allow(clippy::too_many_arguments)]
    fn grad_params_serial(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        grad: &mut GradParams,
    ) -> Result<()> {
        self.grad_params_into(spec, p, w, x, upstream, shape, Some(1), grad)
    }

    /// Parameter fields holding reflection vectors that training
    /// re-normalizes to unit norm after every optimizer step, as the
    /// paper prescribes for ETHER methods (§3.2/§3.3). Empty (the
    /// default) for methods with no reflection geometry — the trainer's
    /// post-step projection is a no-op for them. Keeping this on the op
    /// (not a `MethodKind` match in the trainer) is what lets a new
    /// reflection-family member opt in from its own file.
    fn unit_norm_fields(&self, spec: &MethodSpec) -> &'static [&'static str] {
        let _ = spec;
        &[]
    }

    /// Squared transformation-distance contribution of one matrix/layer
    /// (paper Fig. 4): `‖T − I‖²_F` for multiplicative ops (materialized
    /// by transforming the identity), `‖ΔW‖²_F` for additive ops
    /// (materialized by transforming the zero matrix).
    fn distance_sq(&self, spec: &MethodSpec, p: &ResolvedParams, d: usize, f: usize) -> Result<f64> {
        if self.is_identity() {
            return Ok(0.0);
        }
        if self.is_multiplicative() {
            Ok(self.apply_blocked(spec, p, &Mat::eye(d))?.dist_from_identity().powi(2))
        } else {
            Ok(self.apply_blocked(spec, p, &Mat::zeros(d, f))?.fro().powi(2))
        }
    }

    // -- Composition primitives --------------------------------------------
    //
    // Every host-mergeable family member is *affine in the base weight*:
    // `T(M) = L·M·R + Δ` where `L` (d×d), `R` (f×f) and `Δ` (d×f) depend
    // only on the adapter parameters. The three factor hooks below expose
    // that structure on activations, so the composed on-the-fly sweep in
    // [`crate::peft::apply::MergePlan::execute_activations_stack`] can
    // chain a whole adapter stack `T_k(…T_1(W))·x` around **one** base
    // GEMM with activation-sized scratch — the composition-order recursion
    // itself lives only in `peft/apply.rs` (dispatch discipline), ops just
    // supply their factors.

    /// Whether the three composition factor hooks below faithfully
    /// decompose this op's transform as `T(M) = L·M·R + Δ`. Opt-in per
    /// op; the composed on-the-fly path gates on it (the merged path
    /// needs only `apply_into`).
    fn supports_composition(&self) -> bool {
        false
    }

    /// Right factor on activations: `out = R·x` for `m` columns of an
    /// `f`-dimensional input (`f×m`). Default: `R = I` (copy).
    fn act_right_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let _ = (spec, p, shape);
        out.copy_from_slice(x);
        Ok(())
    }

    /// Left factor on activations: `out = L·y` for `m` columns of a
    /// `d`-dimensional intermediate (`d×m`). Default: `L = I` (copy).
    fn act_left_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        y: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let _ = (spec, p, shape);
        out.copy_from_slice(y);
        Ok(())
    }

    /// Additive term on activations: `out += Δ·x` (`x` is `f×m`, `out`
    /// is `d×m`). Default: `Δ = 0` (no-op).
    fn act_delta_acc(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let _ = (spec, p, x, shape, out);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shared inverse kernels (Woodbury rank-2 for the relaxed reflection).
// ---------------------------------------------------------------------------

/// Per-block 2×2 system for inverting `I − ûûᵀ + v̂v̂ᵀ`: writing the
/// operator as `I + A Bᵀ` with `A = [−û v̂]`, `B = [û v̂]`, Woodbury gives
/// `(I + A Bᵀ)⁻¹ = I − A M⁻¹ Bᵀ` with `M = I₂ + Bᵀ A`. Returns
/// `(m00, m01, m10, m11, det)` of `M`.
fn woodbury_2x2(ub: &[f32], vb: &[f32]) -> Result<(f64, f64, f64, f64, f64)> {
    let (mut c_uu, mut c_uv, mut c_vv) = (0.0f64, 0.0f64, 0.0f64);
    for (&u, &v) in ub.iter().zip(vb) {
        let (u, v) = (u as f64, v as f64);
        c_uu += u * u;
        c_uv += u * v;
        c_vv += v * v;
    }
    let (a, b, c, d) = (1.0 - c_uu, c_uv, -c_uv, 1.0 + c_vv);
    let det = a * d - b * c;
    ensure!(
        det.abs() > 1e-9,
        "relaxed reflection block is numerically singular (û ⊥ v̂): cannot unmerge"
    );
    Ok((a, b, c, d, det))
}

/// Inverse of the left relaxed reflection over a full `d×f` slice pair:
/// `out = (I − ûûᵀ + v̂v̂ᵀ)⁻¹ merged`, per block (pre-normalized û, v̂).
fn ether_plus_left_uninto(
    uh: &[f32],
    vh: &[f32],
    n: usize,
    merged: &[f32],
    f: usize,
    out: &mut [f32],
) -> Result<()> {
    let d = uh.len();
    let db = d / n;
    debug_assert_eq!(merged.len(), d * f);
    debug_assert_eq!(out.len(), merged.len());
    let mut pu = vec![0.0f64; f];
    let mut pv = vec![0.0f64; f];
    for b in 0..n {
        let ub = &uh[b * db..(b + 1) * db];
        let vb = &vh[b * db..(b + 1) * db];
        let (a, bq, c2, d2, det) = woodbury_2x2(ub, vb)?;
        pu.fill(0.0);
        pv.fill(0.0);
        for r in 0..db {
            let row = &merged[(b * db + r) * f..(b * db + r + 1) * f];
            let (u, v) = (ub[r] as f64, vb[r] as f64);
            for c in 0..f {
                pu[c] += u * row[c] as f64;
                pv[c] += v * row[c] as f64;
            }
        }
        // Solve M [s0 s1]ᵀ = [pu pv]ᵀ per column; y = m + û s0 − v̂ s1.
        for c in 0..f {
            let s0 = (d2 * pu[c] - bq * pv[c]) / det;
            let s1 = (-c2 * pu[c] + a * pv[c]) / det;
            pu[c] = s0;
            pv[c] = s1;
        }
        for r in 0..db {
            let off = (b * db + r) * f;
            let (u, v) = (ub[r] as f64, vb[r] as f64);
            for c in 0..f {
                out[off + c] = (merged[off + c] as f64 + u * pu[c] - v * pv[c]) as f32;
            }
        }
    }
    Ok(())
}

/// Inverse of the right relaxed reflection, in place over contiguous
/// rows (column blocks of width `f / n`; pre-normalized û, v̂).
fn ether_plus_right_uninto(
    rows: &mut [f32],
    f: usize,
    uh: &[f32],
    vh: &[f32],
    n: usize,
) -> Result<()> {
    debug_assert_eq!(rows.len() % f, 0);
    let fb = f / n;
    let mut coefs = Vec::with_capacity(n);
    for b in 0..n {
        coefs.push(woodbury_2x2(&uh[b * fb..(b + 1) * fb], &vh[b * fb..(b + 1) * fb])?);
    }
    for row in rows.chunks_mut(f) {
        for (b, &(a, bq, c2, d2, det)) in coefs.iter().enumerate() {
            let seg = &mut row[b * fb..(b + 1) * fb];
            let ub = &uh[b * fb..(b + 1) * fb];
            let vb = &vh[b * fb..(b + 1) * fb];
            let (mut pu, mut pv) = (0.0f64, 0.0f64);
            for c in 0..fb {
                pu += seg[c] as f64 * ub[c] as f64;
                pv += seg[c] as f64 * vb[c] as f64;
            }
            let s0 = (d2 * pu - bq * pv) / det;
            let s1 = (-c2 * pu + a * pv) / det;
            for c in 0..fb {
                seg[c] = (seg[c] as f64 + s0 * ub[c] as f64 - s1 * vb[c] as f64) as f32;
            }
        }
    }
    Ok(())
}

/// DeLoRA's strength-scaled column normalization folded into `A`:
/// `scaled_a[:, t] = a[:, t] · sign·λ / (r · (‖a_t‖·‖b_t‖ + ε))`, so the
/// additive update `ΔW = scaled_a · b` matches
/// `(λ/r) Σ_t (a_t b_tᵀ)/(‖a_t‖‖b_t‖)`. Norms accumulate in f64 in a
/// fixed order, so the scaling is bit-deterministic.
fn delora_scaled_a(
    a: &[f32],
    b: &[f32],
    lambda: f32,
    d: usize,
    r: usize,
    f: usize,
    sign: f64,
) -> Vec<f32> {
    let mut coef = vec![0.0f64; r];
    for (t, ct) in coef.iter_mut().enumerate() {
        let mut na = 0.0f64;
        for i in 0..d {
            let x = a[i * r + t] as f64;
            na += x * x;
        }
        let mut nb = 0.0f64;
        for c in 0..f {
            let x = b[t * f + c] as f64;
            nb += x * x;
        }
        *ct = sign * lambda as f64 / (r as f64 * (na.sqrt() * nb.sqrt() + tf::NORM_EPS));
    }
    let mut out = vec![0.0f32; a.len()];
    for i in 0..d {
        for t in 0..r {
            out[i * r + t] = (a[i * r + t] as f64 * coef[t]) as f32;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Shared gradient kernels (the training-side backward of the family).
// ---------------------------------------------------------------------------

/// Common shape guard for the gradient surface.
fn ensure_grad_shapes(
    op: &dyn TransformOp,
    w: &[f32],
    x: &[f32],
    upstream: &[f32],
    shape: ActShape,
) -> Result<()> {
    let ActShape { d, f, m } = shape;
    ensure!(m > 0, "{}: gradient needs at least one activation column", op.token());
    ensure!(
        w.len() == d * f,
        "{}: base slice length {} != {d}×{f}",
        op.token(),
        w.len()
    );
    ensure!(
        x.len() == f * m,
        "{}: input length {} != {f}×{m}",
        op.token(),
        x.len()
    );
    ensure!(
        upstream.len() == d * m,
        "{}: upstream length {} != {d}×{m}",
        op.token(),
        upstream.len()
    );
    Ok(())
}

/// Chain a gradient w.r.t. the *normalized* vector `û = u·r`,
/// `r = (Σu² + ε)^(−1/2)`, back to the raw parameter `u`, and
/// accumulate: `∂L/∂u = r·gh − r³·(u·gh)·u`. f64 throughout, fixed
/// reduction order.
fn normalize_backward_acc(u: &[f32], gh: &[f64], out: &mut [f32]) {
    debug_assert_eq!(u.len(), gh.len());
    debug_assert_eq!(u.len(), out.len());
    let s: f64 = u.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let r = 1.0 / (s + tf::NORM_EPS).sqrt();
    let dot: f64 = u.iter().zip(gh).map(|(&x, &g)| x as f64 * g).sum();
    let r3 = r * r * r;
    for ((o, &x), &g) in out.iter_mut().zip(u).zip(gh) {
        *o = (*o as f64 + r * g - r3 * dot * x as f64) as f32;
    }
}

/// `∂L/∂u` of the pure reflection `y = z − 2û(ûᵀz)` over all blocks
/// (Householder product rule), accumulated in raw-parameter space
/// (chained through the block normalization). With `s_c = ûᵀz_c` and
/// `t_c = ûᵀg_c` per column, `∂L/∂û = −2·Σ_c (s_c·g_c + t_c·z_c)`.
/// Parallel over blocks — disjoint gradient regions, fixed order
/// within a block.
fn ether_grad_acc(
    threads: Option<usize>,
    u: &[f32],
    n: usize,
    z: &[f32],
    g: &[f32],
    m: usize,
    gu: &mut [f32],
) {
    let d = u.len();
    let db = d / n;
    debug_assert_eq!(z.len(), d * m);
    debug_assert_eq!(g.len(), d * m);
    debug_assert_eq!(gu.len(), d);
    let uh = tf::normalize_blocks(u, n);
    let ptr = SendPtr::new(gu.as_mut_ptr());
    parallel_for_chunks_opt(threads, n, 1, |b0, b1| {
        ptr.claim(b0 * db, (b1 - b0) * db);
        for b in b0..b1 {
            let ub = &uh[b * db..(b + 1) * db];
            let mut gh = vec![0.0f64; db];
            for c in 0..m {
                let (mut s, mut t) = (0.0f64, 0.0f64);
                for r in 0..db {
                    let i = (b * db + r) * m + c;
                    s += ub[r] as f64 * z[i] as f64;
                    t += ub[r] as f64 * g[i] as f64;
                }
                for (r, gh_r) in gh.iter_mut().enumerate() {
                    let i = (b * db + r) * m + c;
                    *gh_r -= 2.0 * (s * g[i] as f64 + t * z[i] as f64);
                }
            }
            // SAFETY: workers receive disjoint block ranges of `gu`.
            let out = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(b * db), db) };
            normalize_backward_acc(&u[b * db..(b + 1) * db], &gh, out);
        }
    });
}

/// `∂L/∂(u, v)` of the relaxed reflection
/// `y = z − û(ûᵀz) + v̂(v̂ᵀz)` (per block), given the input `z` and
/// `g = ∂L/∂y` — used by both sides of ETHER+ (the right factor sees
/// `x` as input and `Wᵀ·(H⁺·g)` as upstream). Parallel over blocks,
/// chained through the block normalization like [`ether_grad_acc`].
#[allow(clippy::too_many_arguments)]
fn relaxed_reflection_grad_acc(
    threads: Option<usize>,
    u: &[f32],
    v: &[f32],
    n: usize,
    z: &[f32],
    g: &[f32],
    m: usize,
    gu: &mut [f32],
    gv: &mut [f32],
) {
    let d = u.len();
    let db = d / n;
    debug_assert_eq!(v.len(), d);
    debug_assert_eq!(z.len(), d * m);
    debug_assert_eq!(g.len(), d * m);
    debug_assert_eq!(gu.len(), d);
    debug_assert_eq!(gv.len(), d);
    let uh = tf::normalize_blocks(u, n);
    let vh = tf::normalize_blocks(v, n);
    let pu = SendPtr::new(gu.as_mut_ptr());
    let pv = SendPtr::new(gv.as_mut_ptr());
    parallel_for_chunks_opt(threads, n, 1, |b0, b1| {
        pu.claim(b0 * db, (b1 - b0) * db);
        pv.claim(b0 * db, (b1 - b0) * db);
        for b in b0..b1 {
            let ub = &uh[b * db..(b + 1) * db];
            let vb = &vh[b * db..(b + 1) * db];
            let mut ghu = vec![0.0f64; db];
            let mut ghv = vec![0.0f64; db];
            for c in 0..m {
                let (mut su, mut tu, mut sv, mut tv) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                for r in 0..db {
                    let i = (b * db + r) * m + c;
                    su += ub[r] as f64 * z[i] as f64;
                    tu += ub[r] as f64 * g[i] as f64;
                    sv += vb[r] as f64 * z[i] as f64;
                    tv += vb[r] as f64 * g[i] as f64;
                }
                for r in 0..db {
                    let i = (b * db + r) * m + c;
                    ghu[r] -= su * g[i] as f64 + tu * z[i] as f64;
                    ghv[r] += sv * g[i] as f64 + tv * z[i] as f64;
                }
            }
            // SAFETY: workers receive disjoint block ranges of gu/gv.
            let ou = unsafe { std::slice::from_raw_parts_mut(pu.get().add(b * db), db) };
            normalize_backward_acc(&u[b * db..(b + 1) * db], &ghu, ou);
            let ov = unsafe { std::slice::from_raw_parts_mut(pv.get().add(b * db), db) };
            normalize_backward_acc(&v[b * db..(b + 1) * db], &ghv, ov);
        }
    });
}

// ---------------------------------------------------------------------------
// The family.
// ---------------------------------------------------------------------------

/// ETHER: block-diagonal hyperplane reflections (paper Eq. 1, §3.4).
pub struct EtherOp;

impl TransformOp for EtherOp {
    fn kind(&self) -> MethodKind {
        MethodKind::Ether
    }

    fn token(&self) -> &'static str {
        "ether"
    }

    fn arity(&self) -> Arity {
        Arity::Blocks
    }

    fn spec_name(&self, spec: &MethodSpec) -> String {
        format!("ether_n{}", spec.n_blocks)
    }

    fn is_multiplicative(&self) -> bool {
        true
    }

    /// Reflections are involutory: `H·H = I` (§3.2), so unmerge is a
    /// second application of the same kernel.
    fn supports_unmerge(&self) -> bool {
        true
    }

    fn unit_norm_fields(&self, _spec: &MethodSpec) -> &'static [&'static str] {
        &["u"]
    }

    fn param_schema(&self, spec: &MethodSpec, d: usize, _f: usize) -> Vec<(&'static str, Vec<usize>)> {
        vec![("u", vec![spec.n_blocks, d / spec.n_blocks])]
    }

    fn apply_blocked(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        Ok(tf::ether_apply(p.get("u"), spec.n_blocks, w))
    }

    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        Ok(tf::ether_apply_serial(p.get("u"), spec.n_blocks, w))
    }

    fn apply_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        src: &[f32],
        _d: usize,
        f: usize,
        out: &mut [f32],
    ) {
        let uh = tf::normalize_blocks(p.get("u"), spec.n_blocks);
        tf::ether_into(&uh, spec.n_blocks, src, f, out);
    }

    fn unmerge_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        merged: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) -> Result<()> {
        self.apply_into(spec, p, merged, d, f, out);
        Ok(())
    }

    fn supports_activations(&self) -> bool {
        true
    }

    /// `(H·W)·x = H·(W·x)`: one base product, then the O(d)-per-column
    /// reflection on the outputs — never the d×f merged matrix.
    fn apply_activations_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        let uh = tf::normalize_blocks(p.get("u"), spec.n_blocks);
        let mut y0 = vec![0.0f32; d * m];
        tf::matmul_tiled_into(w, x, d, f, m, &mut y0);
        tf::ether_into(&uh, spec.n_blocks, &y0, m, out);
        Ok(())
    }

    /// Affine factors: `T(M) = H·M` — the reflection is the left factor.
    fn supports_composition(&self) -> bool {
        true
    }

    fn act_left_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        y: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let uh = tf::normalize_blocks(p.get("u"), spec.n_blocks);
        tf::ether_into(&uh, spec.n_blocks, y, shape.m, out);
        Ok(())
    }

    fn supports_grad(&self) -> bool {
        true
    }

    /// Householder product rule on `y = H(û)·(W·x)`, chained through
    /// the unit normalization (the training loop re-normalizes û after
    /// each step, as the paper prescribes, which keeps the chain term
    /// well-conditioned).
    fn grad_params_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        threads: Option<usize>,
        grad: &mut GradParams,
    ) -> Result<()> {
        ensure_grad_shapes(self, w, x, upstream, shape)?;
        let ActShape { d, f, m } = shape;
        let mut z = vec![0.0f32; d * m];
        tf::matmul_par(threads, w, x, d, f, m, &mut z);
        ether_grad_acc(threads, p.get("u"), spec.n_blocks, &z, upstream, m, grad.get("u"));
        Ok(())
    }
}

/// ETHER+: relaxed one- or two-sided reflections `I − ûûᵀ + v̂v̂ᵀ` (§3.3).
pub struct EtherPlusOp;

impl TransformOp for EtherPlusOp {
    fn kind(&self) -> MethodKind {
        MethodKind::EtherPlus
    }

    fn token(&self) -> &'static str {
        "etherplus"
    }

    fn arity(&self) -> Arity {
        Arity::Blocks
    }

    fn spec_name(&self, spec: &MethodSpec) -> String {
        format!("etherplus_n{}{}", spec.n_blocks, if spec.sides == 1 { "_1s" } else { "" })
    }

    fn is_multiplicative(&self) -> bool {
        true
    }

    /// Invertible through the rank-2 Woodbury identity (per block), as
    /// long as û is not orthogonal to v̂.
    fn supports_unmerge(&self) -> bool {
        true
    }

    fn unit_norm_fields(&self, spec: &MethodSpec) -> &'static [&'static str] {
        if spec.sides == 2 {
            &["u", "v", "ru", "rv"]
        } else {
            &["u", "v"]
        }
    }

    fn param_schema(&self, spec: &MethodSpec, d: usize, f: usize) -> Vec<(&'static str, Vec<usize>)> {
        let n = spec.n_blocks;
        let mut fields = vec![("u", vec![n, d / n]), ("v", vec![n, d / n])];
        if spec.sides == 2 {
            fields.push(("ru", vec![n, f / n]));
            fields.push(("rv", vec![n, f / n]));
        }
        fields
    }

    fn validate(&self, spec: &MethodSpec, mat: &str, d: usize, f: usize) -> Result<()> {
        ensure!(
            spec.n_blocks > 0 && d % spec.n_blocks == 0,
            "{mat}: n_blocks={} must divide rows {d}",
            spec.n_blocks
        );
        if spec.sides == 2 {
            ensure!(
                f % spec.n_blocks == 0,
                "{mat}: n_blocks={} must divide cols {f}",
                spec.n_blocks
            );
        }
        Ok(())
    }

    fn apply_blocked(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        let n = spec.n_blocks;
        let mut out = tf::ether_plus_left(p.get("u"), p.get("v"), n, w);
        if spec.sides == 2 {
            out = tf::ether_plus_right(&out, p.get("ru"), p.get("rv"), n);
        }
        Ok(out)
    }

    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        let n = spec.n_blocks;
        let mut out = tf::ether_plus_left_serial(p.get("u"), p.get("v"), n, w);
        if spec.sides == 2 {
            out = tf::ether_plus_right_serial(&out, p.get("ru"), p.get("rv"), n);
        }
        Ok(out)
    }

    fn apply_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        src: &[f32],
        _d: usize,
        f: usize,
        out: &mut [f32],
    ) {
        let n = spec.n_blocks;
        let uh = tf::normalize_blocks(p.get("u"), n);
        let vh = tf::normalize_blocks(p.get("v"), n);
        tf::ether_plus_left_into(&uh, &vh, n, src, f, out);
        if spec.sides == 2 {
            let ruh = tf::normalize_blocks(p.get("ru"), n);
            let rvh = tf::normalize_blocks(p.get("rv"), n);
            tf::ether_plus_right_rows(out, f, &ruh, &rvh, n);
        }
    }

    fn unmerge_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        merged: &[f32],
        _d: usize,
        f: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let n = spec.n_blocks;
        let uh = tf::normalize_blocks(p.get("u"), n);
        let vh = tf::normalize_blocks(p.get("v"), n);
        if spec.sides == 2 {
            // Merge applied left then right, so unmerge peels the right
            // factor first, then the left.
            let mut tmp = merged.to_vec();
            let ruh = tf::normalize_blocks(p.get("ru"), n);
            let rvh = tf::normalize_blocks(p.get("rv"), n);
            ether_plus_right_uninto(&mut tmp, f, &ruh, &rvh, n)?;
            ether_plus_left_uninto(&uh, &vh, n, &tmp, f, out)
        } else {
            ether_plus_left_uninto(&uh, &vh, n, merged, f, out)
        }
    }

    /// Fig. 4 convention: the left factor's distance on `I_d` plus (for
    /// two-sided specs) the right factor's distance on `I_f`.
    fn distance_sq(&self, spec: &MethodSpec, p: &ResolvedParams, d: usize, f: usize) -> Result<f64> {
        let n = spec.n_blocks;
        let left = tf::ether_plus_left(p.get("u"), p.get("v"), n, &Mat::eye(d));
        let mut acc = left.dist_from_identity().powi(2);
        if spec.sides == 2 {
            let right = tf::ether_plus_right(&Mat::eye(f), p.get("ru"), p.get("rv"), n);
            acc += right.dist_from_identity().powi(2);
        }
        Ok(acc)
    }

    fn supports_activations(&self) -> bool {
        true
    }

    /// `(H⁺·W·H̃⁺)·x = H⁺·(W·(H̃⁺·x))`: the symmetric right factor applies
    /// to the f-dim input columns first, then one base product, then the
    /// left relaxed reflection on the outputs.
    fn apply_activations_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        let n = spec.n_blocks;
        let uh = tf::normalize_blocks(p.get("u"), n);
        let vh = tf::normalize_blocks(p.get("v"), n);
        let mut y0 = vec![0.0f32; d * m];
        if spec.sides == 2 {
            let ruh = tf::normalize_blocks(p.get("ru"), n);
            let rvh = tf::normalize_blocks(p.get("rv"), n);
            let mut xp = vec![0.0f32; f * m];
            tf::ether_plus_left_into(&ruh, &rvh, n, x, m, &mut xp);
            tf::matmul_tiled_into(w, &xp, d, f, m, &mut y0);
        } else {
            tf::matmul_tiled_into(w, x, d, f, m, &mut y0);
        }
        tf::ether_plus_left_into(&uh, &vh, n, &y0, m, out);
        Ok(())
    }

    /// Affine factors: `T(M) = H⁺·M·H̃⁺` — left relaxed reflection on the
    /// d-dim outputs, right (two-sided specs only) on the f-dim inputs.
    fn supports_composition(&self) -> bool {
        true
    }

    fn act_right_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        if spec.sides == 2 {
            let n = spec.n_blocks;
            let ruh = tf::normalize_blocks(p.get("ru"), n);
            let rvh = tf::normalize_blocks(p.get("rv"), n);
            tf::ether_plus_left_into(&ruh, &rvh, n, x, shape.m, out);
        } else {
            out.copy_from_slice(x);
        }
        Ok(())
    }

    fn act_left_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        y: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let n = spec.n_blocks;
        let uh = tf::normalize_blocks(p.get("u"), n);
        let vh = tf::normalize_blocks(p.get("v"), n);
        tf::ether_plus_left_into(&uh, &vh, n, y, shape.m, out);
        Ok(())
    }

    fn supports_grad(&self) -> bool {
        true
    }

    /// Rank-2 relaxation backward (§3.3): the left factor's (û, v̂)
    /// grads use `z = W·x′` (x′ is the right-reflected input) and the
    /// upstream directly; for two-sided specs the right factor's grads
    /// see `x` as input and `Wᵀ·(H⁺·g)` as upstream — H⁺ is symmetric,
    /// so no separate transpose kernel is needed.
    fn grad_params_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        threads: Option<usize>,
        grad: &mut GradParams,
    ) -> Result<()> {
        ensure_grad_shapes(self, w, x, upstream, shape)?;
        let ActShape { d, f, m } = shape;
        let n = spec.n_blocks;
        let (u, v) = (p.get("u"), p.get("v"));
        // Forward recompute: x′ (right-reflected input) and z = W·x′.
        let mut z = vec![0.0f32; d * m];
        if spec.sides == 2 {
            let ruh = tf::normalize_blocks(p.get("ru"), n);
            let rvh = tf::normalize_blocks(p.get("rv"), n);
            let mut xp = vec![0.0f32; f * m];
            tf::ether_plus_left_into(&ruh, &rvh, n, x, m, &mut xp);
            tf::matmul_par(threads, w, &xp, d, f, m, &mut z);
        } else {
            tf::matmul_par(threads, w, x, d, f, m, &mut z);
        }
        {
            let (gu, gv) = grad.get2("u", "v");
            relaxed_reflection_grad_acc(threads, u, v, n, &z, upstream, m, gu, gv);
        }
        if spec.sides == 2 {
            // ∂L/∂x′ = Wᵀ·(H⁺·g); the right factor is the same relaxed
            // reflection acting on the f-dimensional input blocks.
            let uh = tf::normalize_blocks(u, n);
            let vh = tf::normalize_blocks(v, n);
            let mut hg = vec![0.0f32; d * m];
            tf::ether_plus_left_into(&uh, &vh, n, upstream, m, &mut hg);
            let mut gx = vec![0.0f32; f * m];
            tf::matmul_t_par(threads, w, &hg, d, f, m, &mut gx);
            let (gru, grv) = grad.get2("ru", "rv");
            relaxed_reflection_grad_acc(threads, p.get("ru"), p.get("rv"), n, x, &gx, m, gru, grv);
        }
        Ok(())
    }
}

/// OFT: block-diagonal Cayley-orthogonal multipliers, optionally with
/// magnitude refitting.
pub struct OftOp;

impl TransformOp for OftOp {
    fn kind(&self) -> MethodKind {
        MethodKind::Oft
    }

    fn token(&self) -> &'static str {
        "oft"
    }

    fn arity(&self) -> Arity {
        Arity::Blocks
    }

    fn spec_name(&self, spec: &MethodSpec) -> String {
        format!("oft_n{}{}", spec.n_blocks, if spec.magnitude_refit { "_mrf" } else { "" })
    }

    fn is_multiplicative(&self) -> bool {
        true
    }

    /// Cayley blocks are orthogonal, so the inverse is the transpose.
    fn supports_unmerge(&self) -> bool {
        true
    }

    fn param_schema(&self, spec: &MethodSpec, d: usize, f: usize) -> Vec<(&'static str, Vec<usize>)> {
        let n = spec.n_blocks;
        let k = d / n;
        let mut fields = vec![("r", vec![n, k, k])];
        if spec.magnitude_refit {
            fields.push(("mag", vec![f]));
        }
        fields
    }

    fn apply_blocked(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        let blocks = tf::cayley_blocks(p.get("r"), spec.n_blocks, w.rows / spec.n_blocks);
        let scale = if spec.magnitude_refit { Some(p.get("mag")) } else { None };
        Ok(tf::bdmm_scaled(&blocks, w, scale))
    }

    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        let blocks = tf::cayley_blocks(p.get("r"), spec.n_blocks, w.rows / spec.n_blocks);
        let mut out = tf::bdmm_serial(&blocks, w);
        if spec.magnitude_refit {
            let mag = p.get("mag");
            for r in 0..out.rows {
                let row = out.row_mut(r);
                for (c, x) in row.iter_mut().enumerate() {
                    *x *= 1.0 + mag[c];
                }
            }
        }
        Ok(out)
    }

    fn apply_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        src: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) {
        let blocks = tf::cayley_blocks(p.get("r"), spec.n_blocks, d / spec.n_blocks);
        let scale = if spec.magnitude_refit { Some(p.get("mag")) } else { None };
        tf::bdmm_into(&blocks, src, f, scale, out);
    }

    fn unmerge_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        merged: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let blocks = tf::cayley_blocks(p.get("r"), spec.n_blocks, d / spec.n_blocks);
        let qt: Vec<Mat> = blocks.iter().map(Mat::transpose).collect();
        if spec.magnitude_refit {
            let mag = p.get("mag");
            for (c, &m) in mag.iter().enumerate() {
                ensure!(
                    (1.0 + m).abs() > 1e-6,
                    "magnitude refit zeroed column {c} (1 + mag ≈ 0): cannot unmerge"
                );
            }
            let mut tmp = vec![0.0f32; merged.len()];
            for r in 0..d {
                for c in 0..f {
                    tmp[r * f + c] = merged[r * f + c] / (1.0 + mag[c]);
                }
            }
            tf::bdmm_into(&qt, &tmp, f, None, out);
        } else {
            tf::bdmm_into(&qt, merged, f, None, out);
        }
        Ok(())
    }

    fn supports_activations(&self) -> bool {
        true
    }

    /// `(Q·W·diag(1+mag))·x = Q·(W·(diag(1+mag)·x))`: scale the f-dim
    /// input rows, one base product, then the block-diagonal multiply on
    /// the d-dim outputs.
    fn apply_activations_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        let blocks = tf::cayley_blocks(p.get("r"), spec.n_blocks, d / spec.n_blocks);
        let mut y0 = vec![0.0f32; d * m];
        if spec.magnitude_refit {
            let mag = p.get("mag");
            let mut xs = vec![0.0f32; f * m];
            for j in 0..f {
                let s = 1.0 + mag[j];
                for c in 0..m {
                    xs[j * m + c] = x[j * m + c] * s;
                }
            }
            tf::matmul_tiled_into(w, &xs, d, f, m, &mut y0);
        } else {
            tf::matmul_tiled_into(w, x, d, f, m, &mut y0);
        }
        tf::bdmm_into(&blocks, &y0, m, None, out);
        Ok(())
    }

    /// Affine factors: `T(M) = Q·M·diag(1+mag)` — Cayley blocks left,
    /// the magnitude refit (when present) right on the f-dim inputs.
    fn supports_composition(&self) -> bool {
        true
    }

    fn act_right_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { f, m, .. } = shape;
        if spec.magnitude_refit {
            let mag = p.get("mag");
            for j in 0..f {
                let s = 1.0 + mag[j];
                for c in 0..m {
                    out[j * m + c] = x[j * m + c] * s;
                }
            }
        } else {
            out.copy_from_slice(x);
        }
        Ok(())
    }

    fn act_left_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        y: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, m, .. } = shape;
        let blocks = tf::cayley_blocks(p.get("r"), spec.n_blocks, d / spec.n_blocks);
        tf::bdmm_into(&blocks, y, m, None, out);
        Ok(())
    }

    fn supports_grad(&self) -> bool {
        true
    }

    /// Cayley backward: with `Q = (I+S)·M`, `M = (I−S)⁻¹`, the chain
    /// rule gives `dQ = (I+Q)·dS·M`, hence `G_S = (I+Q)ᵀ·G_Q·Mᵀ` and
    /// `G_R = ½(G_S − G_Sᵀ)` for `S = ½(R − Rᵀ)`, where `G_Q = g·zᵀ`
    /// per block over `z = W·x̃` (x̃ is the magnitude-scaled input when
    /// refitting). The magnitude grad is
    /// `∂L/∂mag_c = Σ_m x[c,m]·(Wᵀ·Qᵀ·g)[c,m]`.
    fn grad_params_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        threads: Option<usize>,
        grad: &mut GradParams,
    ) -> Result<()> {
        ensure_grad_shapes(self, w, x, upstream, shape)?;
        let ActShape { d, f, m } = shape;
        let n = spec.n_blocks;
        let k = d / n;
        let r = p.get("r");
        // Forward recompute: x̃ (magnitude-scaled input) and z = W·x̃.
        let xs_owned: Option<Vec<f32>> = if spec.magnitude_refit {
            let mag = p.get("mag");
            let mut scaled = vec![0.0f32; f * m];
            for j in 0..f {
                let s = 1.0 + mag[j];
                for c in 0..m {
                    scaled[j * m + c] = x[j * m + c] * s;
                }
            }
            Some(scaled)
        } else {
            None
        };
        let xs: &[f32] = xs_owned.as_deref().unwrap_or(x);
        let mut z = vec![0.0f32; d * m];
        tf::matmul_par(threads, w, xs, d, f, m, &mut z);
        let blocks = tf::cayley_blocks(r, n, k);
        {
            let gr = grad.get("r");
            let ptr = SendPtr::new(gr.as_mut_ptr());
            let (z, blocks) = (&z, &blocks);
            parallel_for_chunks_opt(threads, n, 1, |b0, b1| {
                ptr.claim(b0 * k * k, (b1 - b0) * k * k);
                for b in b0..b1 {
                    // G_Q[i][j] = Σ_c g[bk+i, c]·z[bk+j, c]  (f64).
                    let mut gq = vec![0.0f64; k * k];
                    for i in 0..k {
                        for j in 0..k {
                            let mut acc = 0.0f64;
                            for c in 0..m {
                                acc += upstream[(b * k + i) * m + c] as f64
                                    * z[(b * k + j) * m + c] as f64;
                            }
                            gq[i * k + j] = acc;
                        }
                    }
                    // M = (I − S)⁻¹ recomputed from this block of R.
                    let blk = &r[b * k * k..(b + 1) * k * k];
                    let mut s = Mat::zeros(k, k);
                    for i in 0..k {
                        for j in 0..k {
                            *s.at_mut(i, j) = 0.5 * (blk[i * k + j] - blk[j * k + i]);
                        }
                    }
                    let minv = solve::gauss_jordan_inv(&Mat::eye(k).sub(&s))
                        .expect("I − S is always invertible for skew-symmetric S");
                    let q = &blocks[b];
                    // T = (I+Q)ᵀ·G_Q, then G_S = T·Mᵀ (f64, fixed order).
                    let mut t = vec![0.0f64; k * k];
                    for i in 0..k {
                        for j in 0..k {
                            let mut acc = gq[i * k + j];
                            for l in 0..k {
                                acc += q.at(l, i) as f64 * gq[l * k + j];
                            }
                            t[i * k + j] = acc;
                        }
                    }
                    let mut gs = vec![0.0f64; k * k];
                    for i in 0..k {
                        for j in 0..k {
                            let mut acc = 0.0f64;
                            for l in 0..k {
                                acc += t[i * k + l] * minv.at(j, l) as f64;
                            }
                            gs[i * k + j] = acc;
                        }
                    }
                    // SAFETY: workers receive disjoint block ranges of gr.
                    let out = unsafe {
                        std::slice::from_raw_parts_mut(ptr.get().add(b * k * k), k * k)
                    };
                    for i in 0..k {
                        for j in 0..k {
                            let gr_ij = 0.5 * (gs[i * k + j] - gs[j * k + i]);
                            let o = &mut out[i * k + j];
                            *o = (*o as f64 + gr_ij) as f32;
                        }
                    }
                }
            });
        }
        if spec.magnitude_refit {
            // Qᵀ·g (f64), block-diagonal transpose multiply, then
            // gmag[c] = Σ_i W[i,c]·Σ_cc (Qᵀg)[i,cc]·x[c,cc].
            let mut qtg = vec![0.0f64; d * m];
            for (b, q) in blocks.iter().enumerate() {
                for j in 0..k {
                    for c in 0..m {
                        let mut acc = 0.0f64;
                        for i in 0..k {
                            acc += q.at(i, j) as f64 * upstream[(b * k + i) * m + c] as f64;
                        }
                        qtg[(b * k + j) * m + c] = acc;
                    }
                }
            }
            let gmag = grad.get("mag");
            let ptr = SendPtr::new(gmag.as_mut_ptr());
            let qtg = &qtg;
            parallel_for_chunks_opt(threads, f, 16, |c0, c1| {
                ptr.claim(c0, c1 - c0);
                for cidx in c0..c1 {
                    let mut acc = 0.0f64;
                    for i in 0..d {
                        let wv = w[i * f + cidx] as f64;
                        if wv == 0.0 {
                            continue;
                        }
                        let mut inner = 0.0f64;
                        for c in 0..m {
                            inner += qtg[i * m + c] * x[cidx * m + c] as f64;
                        }
                        acc += wv * inner;
                    }
                    // SAFETY: workers receive disjoint column ranges.
                    unsafe {
                        let o = ptr.get().add(cidx);
                        *o = (*o as f64 + acc) as f32;
                    }
                }
            });
        }
        Ok(())
    }
}

/// Naive: unconstrained block-diagonal multipliers `I + R` (§5.3).
pub struct NaiveOp;

impl TransformOp for NaiveOp {
    fn kind(&self) -> MethodKind {
        MethodKind::Naive
    }

    fn token(&self) -> &'static str {
        "naive"
    }

    fn arity(&self) -> Arity {
        Arity::Blocks
    }

    fn spec_name(&self, spec: &MethodSpec) -> String {
        format!("naive_n{}", spec.n_blocks)
    }

    fn is_multiplicative(&self) -> bool {
        true
    }

    /// Invertible whenever every `I + R` block is non-singular.
    fn supports_unmerge(&self) -> bool {
        true
    }

    fn param_schema(&self, spec: &MethodSpec, d: usize, _f: usize) -> Vec<(&'static str, Vec<usize>)> {
        let n = spec.n_blocks;
        let k = d / n;
        vec![("r", vec![n, k, k])]
    }

    fn apply_blocked(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        let blocks = tf::naive_blocks(p.get("r"), spec.n_blocks, w.rows / spec.n_blocks);
        Ok(tf::bdmm(&blocks, w))
    }

    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        let blocks = tf::naive_blocks(p.get("r"), spec.n_blocks, w.rows / spec.n_blocks);
        Ok(tf::bdmm_serial(&blocks, w))
    }

    fn apply_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        src: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) {
        let blocks = tf::naive_blocks(p.get("r"), spec.n_blocks, d / spec.n_blocks);
        tf::bdmm_into(&blocks, src, f, None, out);
    }

    fn unmerge_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        merged: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let blocks = tf::naive_blocks(p.get("r"), spec.n_blocks, d / spec.n_blocks);
        let inv: Vec<Mat> = blocks
            .iter()
            .map(solve::gauss_jordan_inv)
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("naive block I + R is singular: cannot unmerge"))?;
        tf::bdmm_into(&inv, merged, f, None, out);
        Ok(())
    }

    fn supports_activations(&self) -> bool {
        true
    }

    /// `((I+R)·W)·x = (I+R)·(W·x)`.
    fn apply_activations_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        let blocks = tf::naive_blocks(p.get("r"), spec.n_blocks, d / spec.n_blocks);
        let mut y0 = vec![0.0f32; d * m];
        tf::matmul_tiled_into(w, x, d, f, m, &mut y0);
        tf::bdmm_into(&blocks, &y0, m, None, out);
        Ok(())
    }

    /// Affine factors: `T(M) = (I+R)·M` — the block multiplier is the
    /// left factor.
    fn supports_composition(&self) -> bool {
        true
    }

    fn act_left_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        y: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, m, .. } = shape;
        let blocks = tf::naive_blocks(p.get("r"), spec.n_blocks, d / spec.n_blocks);
        tf::bdmm_into(&blocks, y, m, None, out);
        Ok(())
    }

    fn supports_grad(&self) -> bool {
        true
    }

    /// `y = (I+R)·z` per block with `z = W·x`, so `∂L/∂R = g·zᵀ`
    /// blockwise — the unconstrained control's backward is the plain
    /// outer product.
    fn grad_params_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        threads: Option<usize>,
        grad: &mut GradParams,
    ) -> Result<()> {
        ensure_grad_shapes(self, w, x, upstream, shape)?;
        let _ = p;
        let ActShape { d, f, m } = shape;
        let n = spec.n_blocks;
        let k = d / n;
        let mut z = vec![0.0f32; d * m];
        tf::matmul_par(threads, w, x, d, f, m, &mut z);
        let gr = grad.get("r");
        let ptr = SendPtr::new(gr.as_mut_ptr());
        let z = &z;
        parallel_for_chunks_opt(threads, n, 1, |b0, b1| {
            ptr.claim(b0 * k * k, (b1 - b0) * k * k);
            for b in b0..b1 {
                // SAFETY: workers receive disjoint block ranges of gr.
                let out =
                    unsafe { std::slice::from_raw_parts_mut(ptr.get().add(b * k * k), k * k) };
                for i in 0..k {
                    for j in 0..k {
                        let mut acc = 0.0f64;
                        for c in 0..m {
                            acc += upstream[(b * k + i) * m + c] as f64
                                * z[(b * k + j) * m + c] as f64;
                        }
                        let o = &mut out[i * k + j];
                        *o = (*o as f64 + acc) as f32;
                    }
                }
            }
        });
        Ok(())
    }
}

/// LoRA: additive low-rank update `W + A B`.
pub struct LoraOp;

impl TransformOp for LoraOp {
    fn kind(&self) -> MethodKind {
        MethodKind::Lora
    }

    fn token(&self) -> &'static str {
        "lora"
    }

    fn arity(&self) -> Arity {
        Arity::Rank
    }

    fn spec_name(&self, spec: &MethodSpec) -> String {
        format!("lora_r{}", spec.rank)
    }

    /// Additive updates invert exactly by subtraction.
    fn supports_unmerge(&self) -> bool {
        true
    }

    fn param_schema(&self, spec: &MethodSpec, d: usize, f: usize) -> Vec<(&'static str, Vec<usize>)> {
        vec![("a", vec![d, spec.rank]), ("b", vec![spec.rank, f])]
    }

    fn validate(&self, spec: &MethodSpec, mat: &str, _d: usize, _f: usize) -> Result<()> {
        ensure!(spec.rank > 0, "{mat}: lora rank must be > 0");
        Ok(())
    }

    fn apply_blocked(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        let a = Mat::from_vec(w.rows, spec.rank, p.get("a").to_vec());
        let b = Mat::from_vec(spec.rank, w.cols, p.get("b").to_vec());
        Ok(tf::lora_apply(&a, &b, w))
    }

    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        self.apply_blocked(spec, p, w)
    }

    fn apply_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        src: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) {
        tf::lora_into(p.get("a"), p.get("b"), src, d, spec.rank, f, out);
    }

    fn unmerge_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        merged: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let neg_a: Vec<f32> = p.get("a").iter().map(|x| -x).collect();
        tf::lora_into(&neg_a, p.get("b"), merged, d, spec.rank, f, out);
        Ok(())
    }

    fn supports_activations(&self) -> bool {
        true
    }

    /// `(W + A·B)·x = W·x + A·(B·x)` — the classic low-rank shortcut;
    /// scratch is the r×m intermediate only.
    fn apply_activations_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        tf::matmul_tiled_into(w, x, d, f, m, out);
        tf::lora_activations_acc(p.get("a"), p.get("b"), x, d, spec.rank, f, m, out);
        Ok(())
    }

    /// Affine factors: `T(M) = M + A·B` — purely additive (`Δ = A·B`).
    fn supports_composition(&self) -> bool {
        true
    }

    fn act_delta_acc(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        tf::lora_activations_acc(p.get("a"), p.get("b"), x, d, spec.rank, f, m, out);
        Ok(())
    }

    fn supports_grad(&self) -> bool {
        true
    }

    /// Low-rank backward: `∂L/∂A = g·(B·x)ᵀ` and `∂L/∂B = (Aᵀ·g)·xᵀ` —
    /// nothing larger than an r×m intermediate is materialized.
    fn grad_params_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        threads: Option<usize>,
        grad: &mut GradParams,
    ) -> Result<()> {
        ensure_grad_shapes(self, w, x, upstream, shape)?;
        let ActShape { d, f, m } = shape;
        let rk = spec.rank;
        let (a, b) = (p.get("a"), p.get("b"));
        // h = B·x and ag = Aᵀ·g, both r×m in f64 (fixed order).
        let mut h = vec![0.0f64; rk * m];
        for t in 0..rk {
            let brow = &b[t * f..(t + 1) * f];
            for c in 0..m {
                let mut acc = 0.0f64;
                for (j, &bv) in brow.iter().enumerate() {
                    acc += bv as f64 * x[j * m + c] as f64;
                }
                h[t * m + c] = acc;
            }
        }
        let mut ag = vec![0.0f64; rk * m];
        for t in 0..rk {
            for c in 0..m {
                let mut acc = 0.0f64;
                for i in 0..d {
                    acc += a[i * rk + t] as f64 * upstream[i * m + c] as f64;
                }
                ag[t * m + c] = acc;
            }
        }
        {
            let ga = grad.get("a");
            let ptr = SendPtr::new(ga.as_mut_ptr());
            let h = &h;
            parallel_for_chunks_opt(threads, d, 16, |r0, r1| {
                ptr.claim(r0 * rk, (r1 - r0) * rk);
                for i in r0..r1 {
                    // SAFETY: workers receive disjoint row ranges of ga.
                    let out =
                        unsafe { std::slice::from_raw_parts_mut(ptr.get().add(i * rk), rk) };
                    for (t, o) in out.iter_mut().enumerate() {
                        let mut acc = 0.0f64;
                        for c in 0..m {
                            acc += upstream[i * m + c] as f64 * h[t * m + c];
                        }
                        *o = (*o as f64 + acc) as f32;
                    }
                }
            });
        }
        {
            let gb = grad.get("b");
            let ptr = SendPtr::new(gb.as_mut_ptr());
            let ag = &ag;
            parallel_for_chunks_opt(threads, f, 16, |j0, j1| {
                ptr.claim_strided(j0, f, rk, j1 - j0);
                for j in j0..j1 {
                    for t in 0..rk {
                        let mut acc = 0.0f64;
                        for c in 0..m {
                            acc += ag[t * m + c] * x[j * m + c] as f64;
                        }
                        // SAFETY: workers receive disjoint column sets.
                        unsafe {
                            let o = ptr.get().add(t * f + j);
                            *o = (*o as f64 + acc) as f32;
                        }
                    }
                }
            });
        }
        Ok(())
    }
}

/// VeRA: shared frozen random projections with tiny trainable scalings.
/// Host-mergeable: no — the frozen projections are jax-seeded HLO
/// constants the host cannot reproduce bit-exactly.
pub struct VeraOp;

impl TransformOp for VeraOp {
    fn kind(&self) -> MethodKind {
        MethodKind::Vera
    }

    fn token(&self) -> &'static str {
        "vera"
    }

    fn arity(&self) -> Arity {
        Arity::Rank
    }

    fn spec_name(&self, spec: &MethodSpec) -> String {
        format!("vera_r{}", spec.rank)
    }

    fn host_mergeable(&self) -> bool {
        false
    }

    fn param_schema(&self, spec: &MethodSpec, _d: usize, f: usize) -> Vec<(&'static str, Vec<usize>)> {
        vec![("dv", vec![spec.rank]), ("bv", vec![f])]
    }

    fn validate(&self, spec: &MethodSpec, mat: &str, _d: usize, _f: usize) -> Result<()> {
        ensure!(spec.rank > 0, "{mat}: vera rank must be > 0");
        Ok(())
    }

    fn apply_blocked(&self, _spec: &MethodSpec, _p: &ResolvedParams, _w: &Mat) -> Result<Mat> {
        bail!("host merge unsupported for vera (use the merge artifact)")
    }

    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        self.apply_blocked(spec, p, w)
    }

    fn apply_into(
        &self,
        _spec: &MethodSpec,
        _p: &ResolvedParams,
        _src: &[f32],
        _d: usize,
        _f: usize,
        _out: &mut [f32],
    ) {
        unreachable!("vera is rejected by host_mergeable() before any plan sweep")
    }
}

/// DeLoRA-style normalized low-rank update with a decoupled strength:
/// `W + (λ/r) Σ_t (a_t b_tᵀ) / (‖a_t‖‖b_t‖)` — the update's direction
/// (column/row-normalized dyads) and magnitude (the scalar λ) are
/// learned independently, which bounds the weight change like ETHER's
/// reflections bound theirs. Host-only family member added through the
/// registry; the worked example of the one-file extension path.
pub struct DeloraOp;

impl TransformOp for DeloraOp {
    fn kind(&self) -> MethodKind {
        MethodKind::Delora
    }

    fn token(&self) -> &'static str {
        "delora"
    }

    fn arity(&self) -> Arity {
        Arity::Rank
    }

    fn spec_name(&self, spec: &MethodSpec) -> String {
        format!("delora_r{}", spec.rank)
    }

    /// Additive updates invert exactly by subtraction.
    fn supports_unmerge(&self) -> bool {
        true
    }

    fn param_schema(&self, spec: &MethodSpec, d: usize, f: usize) -> Vec<(&'static str, Vec<usize>)> {
        vec![("a", vec![d, spec.rank]), ("b", vec![spec.rank, f]), ("lambda", vec![1])]
    }

    fn validate(&self, spec: &MethodSpec, mat: &str, _d: usize, _f: usize) -> Result<()> {
        ensure!(spec.rank > 0, "{mat}: delora rank must be > 0");
        Ok(())
    }

    fn apply_blocked(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        let (d, f, r) = (w.rows, w.cols, spec.rank);
        let sa = delora_scaled_a(p.get("a"), p.get("b"), p.get("lambda")[0], d, r, f, 1.0);
        let a = Mat::from_vec(d, r, sa);
        let b = Mat::from_vec(r, f, p.get("b").to_vec());
        Ok(tf::lora_apply(&a, &b, w))
    }

    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        self.apply_blocked(spec, p, w)
    }

    fn apply_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        src: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) {
        let r = spec.rank;
        let sa = delora_scaled_a(p.get("a"), p.get("b"), p.get("lambda")[0], d, r, f, 1.0);
        tf::lora_into(&sa, p.get("b"), src, d, r, f, out);
    }

    fn unmerge_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        merged: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let r = spec.rank;
        let sa = delora_scaled_a(p.get("a"), p.get("b"), p.get("lambda")[0], d, r, f, -1.0);
        tf::lora_into(&sa, p.get("b"), merged, d, r, f, out);
        Ok(())
    }

    fn supports_activations(&self) -> bool {
        true
    }

    /// Same low-rank shortcut as LoRA, with the strength-scaled `A`.
    fn apply_activations_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        let r = spec.rank;
        let sa = delora_scaled_a(p.get("a"), p.get("b"), p.get("lambda")[0], d, r, f, 1.0);
        tf::matmul_tiled_into(w, x, d, f, m, out);
        tf::lora_activations_acc(&sa, p.get("b"), x, d, r, f, m, out);
        Ok(())
    }

    /// Affine factors: purely additive, `Δ` is the normalized
    /// strength-scaled low-rank update.
    fn supports_composition(&self) -> bool {
        true
    }

    fn act_delta_acc(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        let r = spec.rank;
        let sa = delora_scaled_a(p.get("a"), p.get("b"), p.get("lambda")[0], d, r, f, 1.0);
        tf::lora_activations_acc(&sa, p.get("b"), x, d, r, f, m, out);
        Ok(())
    }

    fn supports_grad(&self) -> bool {
        true
    }

    /// Backward of the normalized, strength-scaled update
    /// `ΔW = (λ/r)·Σ_t a_t b_tᵀ/(‖a_t‖‖b_t‖ + ε)` (DeLoRA's decoupled
    /// direction/magnitude view): with `p_t = a_tᵀ·g`, `q_t = b_t·x`
    /// (per column) and `α_t = Σ_c p_t[c]·q_t[c]`, each component's
    /// direct term mirrors LoRA with coefficient `c_t = λ/(r·s_t)`,
    /// `s_t = ‖a_t‖‖b_t‖ + ε`; the norm chain subtracts the radial
    /// component `λ‖b_t‖α_t/(r·s_t²·‖a_t‖)·a_t` (and symmetrically for
    /// `b_t`); `∂L/∂λ = Σ_t α_t/(r·s_t)`.
    fn grad_params_into(
        &self,
        spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        threads: Option<usize>,
        grad: &mut GradParams,
    ) -> Result<()> {
        ensure_grad_shapes(self, w, x, upstream, shape)?;
        let ActShape { d, f, m } = shape;
        let rk = spec.rank;
        let (a, b) = (p.get("a"), p.get("b"));
        let lam = p.get("lambda")[0] as f64;
        let rk_f = rk as f64;
        // Per-component norms, coefficients and projections (f64).
        let mut na = vec![0.0f64; rk];
        let mut nb = vec![0.0f64; rk];
        for t in 0..rk {
            let mut sa = 0.0f64;
            for i in 0..d {
                let v = a[i * rk + t] as f64;
                sa += v * v;
            }
            na[t] = sa.sqrt().max(1e-12);
            let mut sb = 0.0f64;
            for j in 0..f {
                let v = b[t * f + j] as f64;
                sb += v * v;
            }
            nb[t] = sb.sqrt().max(1e-12);
        }
        let s: Vec<f64> = (0..rk).map(|t| na[t] * nb[t] + tf::NORM_EPS).collect();
        let coef: Vec<f64> = (0..rk).map(|t| lam / (rk_f * s[t])).collect();
        // p_t[c] = a_tᵀ·g_c, q_t[c] = b_t·x_c, α_t = Σ_c p_t·q_t.
        let mut pg = vec![0.0f64; rk * m];
        let mut qx = vec![0.0f64; rk * m];
        for t in 0..rk {
            for c in 0..m {
                let mut acc = 0.0f64;
                for i in 0..d {
                    acc += a[i * rk + t] as f64 * upstream[i * m + c] as f64;
                }
                pg[t * m + c] = acc;
                let mut acc = 0.0f64;
                for j in 0..f {
                    acc += b[t * f + j] as f64 * x[j * m + c] as f64;
                }
                qx[t * m + c] = acc;
            }
        }
        let alpha: Vec<f64> =
            (0..rk).map(|t| (0..m).map(|c| pg[t * m + c] * qx[t * m + c]).sum()).collect();
        let ra: Vec<f64> =
            (0..rk).map(|t| lam * nb[t] * alpha[t] / (rk_f * s[t] * s[t] * na[t])).collect();
        let rb: Vec<f64> =
            (0..rk).map(|t| lam * na[t] * alpha[t] / (rk_f * s[t] * s[t] * nb[t])).collect();
        {
            let ga = grad.get("a");
            let ptr = SendPtr::new(ga.as_mut_ptr());
            let (qx, coef, ra) = (&qx, &coef, &ra);
            parallel_for_chunks_opt(threads, d, 16, |r0, r1| {
                ptr.claim(r0 * rk, (r1 - r0) * rk);
                for i in r0..r1 {
                    // SAFETY: workers receive disjoint row ranges of ga.
                    let out =
                        unsafe { std::slice::from_raw_parts_mut(ptr.get().add(i * rk), rk) };
                    for (t, o) in out.iter_mut().enumerate() {
                        let mut acc = 0.0f64;
                        for c in 0..m {
                            acc += upstream[i * m + c] as f64 * qx[t * m + c];
                        }
                        let g = coef[t] * acc - ra[t] * a[i * rk + t] as f64;
                        *o = (*o as f64 + g) as f32;
                    }
                }
            });
        }
        {
            let gb = grad.get("b");
            let ptr = SendPtr::new(gb.as_mut_ptr());
            let (pg, coef, rb) = (&pg, &coef, &rb);
            parallel_for_chunks_opt(threads, f, 16, |j0, j1| {
                ptr.claim_strided(j0, f, rk, j1 - j0);
                for j in j0..j1 {
                    for t in 0..rk {
                        let mut acc = 0.0f64;
                        for c in 0..m {
                            acc += pg[t * m + c] * x[j * m + c] as f64;
                        }
                        let g = coef[t] * acc - rb[t] * b[t * f + j] as f64;
                        // SAFETY: workers receive disjoint column sets.
                        unsafe {
                            let o = ptr.get().add(t * f + j);
                            *o = (*o as f64 + g) as f32;
                        }
                    }
                }
            });
        }
        let glam = grad.get("lambda");
        let dlam: f64 = (0..rk).map(|t| alpha[t] / (rk_f * s[t])).sum();
        glam[0] = (glam[0] as f64 + dlam) as f32;
        Ok(())
    }
}

/// HyperAdapt-style high-rank row/column scaling (arXiv:2509.18629):
/// `T(W) = diag(1+r)·W·diag(1+c)` — a full-rank multiplicative update
/// from just `d + f` parameters per matrix, the diagonal counterpart to
/// OFT's block-orthogonal multipliers. Host-only family member added
/// through the registry like [`DeloraOp`]: one struct in this file buys
/// merge, exact unmerge (divide out the scalings), the merge-free
/// activation path, composition factors and FD-checked gradients.
pub struct HyperAdaptOp;

impl TransformOp for HyperAdaptOp {
    fn kind(&self) -> MethodKind {
        MethodKind::HyperAdapt
    }

    fn token(&self) -> &'static str {
        "hyperadapt"
    }

    fn arity(&self) -> Arity {
        Arity::Fixed
    }

    fn spec_name(&self, _spec: &MethodSpec) -> String {
        "hyperadapt".into()
    }

    fn is_multiplicative(&self) -> bool {
        true
    }

    /// Diagonal scalings invert by division (guarded against zeroed
    /// factors at unmerge time).
    fn supports_unmerge(&self) -> bool {
        true
    }

    fn param_schema(&self, _spec: &MethodSpec, d: usize, f: usize) -> Vec<(&'static str, Vec<usize>)> {
        vec![("r", vec![d]), ("c", vec![f])]
    }

    /// No block structure: the default multiplicative divisibility check
    /// does not apply (a Fixed-arity spec carries the unused `n_blocks`
    /// default).
    fn validate(&self, _spec: &MethodSpec, _mat: &str, _d: usize, _f: usize) -> Result<()> {
        Ok(())
    }

    fn apply_blocked(&self, _spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        let (r, c) = (p.get("r"), p.get("c"));
        let mut out = w.clone();
        for i in 0..out.rows {
            let row = out.row_mut(i);
            let s = 1.0 + r[i];
            for (j, x) in row.iter_mut().enumerate() {
                *x *= s * (1.0 + c[j]);
            }
        }
        Ok(out)
    }

    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        self.apply_blocked(spec, p, w)
    }

    fn apply_into(
        &self,
        _spec: &MethodSpec,
        p: &ResolvedParams,
        src: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) {
        let (r, c) = (p.get("r"), p.get("c"));
        for i in 0..d {
            let s = 1.0 + r[i];
            for j in 0..f {
                out[i * f + j] = src[i * f + j] * s * (1.0 + c[j]);
            }
        }
    }

    fn unmerge_into(
        &self,
        _spec: &MethodSpec,
        p: &ResolvedParams,
        merged: &[f32],
        d: usize,
        f: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let (r, c) = (p.get("r"), p.get("c"));
        for (i, &ri) in r.iter().enumerate() {
            ensure!(
                (1.0 + ri).abs() > 1e-6,
                "hyperadapt zeroed row {i} (1 + r ≈ 0): cannot unmerge"
            );
        }
        for (j, &cj) in c.iter().enumerate() {
            ensure!(
                (1.0 + cj).abs() > 1e-6,
                "hyperadapt zeroed column {j} (1 + c ≈ 0): cannot unmerge"
            );
        }
        for i in 0..d {
            let s = 1.0 + r[i];
            for j in 0..f {
                out[i * f + j] = merged[i * f + j] / (s * (1.0 + c[j]));
            }
        }
        Ok(())
    }

    /// `‖diag(1+r) − I_d‖²_F + ‖diag(1+c) − I_f‖²_F` — the two factors'
    /// distances, following the two-sided ETHER+ convention.
    fn distance_sq(&self, _spec: &MethodSpec, p: &ResolvedParams, _d: usize, _f: usize) -> Result<f64> {
        let rr: f64 = p.get("r").iter().map(|&v| (v as f64) * (v as f64)).sum();
        let cc: f64 = p.get("c").iter().map(|&v| (v as f64) * (v as f64)).sum();
        Ok(rr + cc)
    }

    fn supports_activations(&self) -> bool {
        true
    }

    /// `(diag(1+r)·W·diag(1+c))·x`: scale the f-dim input rows, one base
    /// product, then scale the d-dim output rows — O(d+f) per column on
    /// top of the base product.
    fn apply_activations_into(
        &self,
        _spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        let (r, c) = (p.get("r"), p.get("c"));
        let mut xs = vec![0.0f32; f * m];
        for j in 0..f {
            let s = 1.0 + c[j];
            for cc in 0..m {
                xs[j * m + cc] = x[j * m + cc] * s;
            }
        }
        tf::matmul_tiled_into(w, &xs, d, f, m, out);
        for i in 0..d {
            let s = 1.0 + r[i];
            for cc in 0..m {
                out[i * m + cc] *= s;
            }
        }
        Ok(())
    }

    /// Affine factors: `L = diag(1+r)`, `R = diag(1+c)`, `Δ = 0`.
    fn supports_composition(&self) -> bool {
        true
    }

    fn act_right_into(
        &self,
        _spec: &MethodSpec,
        p: &ResolvedParams,
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { f, m, .. } = shape;
        let c = p.get("c");
        for j in 0..f {
            let s = 1.0 + c[j];
            for cc in 0..m {
                out[j * m + cc] = x[j * m + cc] * s;
            }
        }
        Ok(())
    }

    fn act_left_into(
        &self,
        _spec: &MethodSpec,
        p: &ResolvedParams,
        y: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, m, .. } = shape;
        let r = p.get("r");
        for i in 0..d {
            let s = 1.0 + r[i];
            for cc in 0..m {
                out[i * m + cc] = y[i * m + cc] * s;
            }
        }
        Ok(())
    }

    fn supports_grad(&self) -> bool {
        true
    }

    /// With `x̃ = diag(1+c)·x` and `z = W·x̃`:
    /// `∂L/∂r_i = Σ_m g[i,m]·z[i,m]` and
    /// `∂L/∂c_j = Σ_m x[j,m]·(Wᵀ·diag(1+r)·g)[j,m]` — plain product
    /// rules through the two diagonal factors.
    fn grad_params_into(
        &self,
        _spec: &MethodSpec,
        p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        threads: Option<usize>,
        grad: &mut GradParams,
    ) -> Result<()> {
        ensure_grad_shapes(self, w, x, upstream, shape)?;
        let ActShape { d, f, m } = shape;
        let (r, c) = (p.get("r"), p.get("c"));
        // Forward recompute: x̃ = diag(1+c)·x and z = W·x̃.
        let mut xs = vec![0.0f32; f * m];
        for j in 0..f {
            let s = 1.0 + c[j];
            for cc in 0..m {
                xs[j * m + cc] = x[j * m + cc] * s;
            }
        }
        let mut z = vec![0.0f32; d * m];
        tf::matmul_par(threads, w, &xs, d, f, m, &mut z);
        {
            let gr = grad.get("r");
            let ptr = SendPtr::new(gr.as_mut_ptr());
            let z = &z;
            parallel_for_chunks_opt(threads, d, 16, |r0, r1| {
                ptr.claim(r0, r1 - r0);
                for i in r0..r1 {
                    let mut acc = 0.0f64;
                    for cc in 0..m {
                        acc += upstream[i * m + cc] as f64 * z[i * m + cc] as f64;
                    }
                    // SAFETY: workers receive disjoint row ranges of gr.
                    unsafe {
                        let o = ptr.get().add(i);
                        *o = (*o as f64 + acc) as f32;
                    }
                }
            });
        }
        // sg = diag(1+r)·g, then gx = Wᵀ·sg (f×m).
        let mut sg = vec![0.0f32; d * m];
        for i in 0..d {
            let s = 1.0 + r[i];
            for cc in 0..m {
                sg[i * m + cc] = upstream[i * m + cc] * s;
            }
        }
        let mut gx = vec![0.0f32; f * m];
        tf::matmul_t_par(threads, w, &sg, d, f, m, &mut gx);
        {
            let gc = grad.get("c");
            let ptr = SendPtr::new(gc.as_mut_ptr());
            let gx = &gx;
            parallel_for_chunks_opt(threads, f, 16, |j0, j1| {
                ptr.claim(j0, j1 - j0);
                for j in j0..j1 {
                    let mut acc = 0.0f64;
                    for cc in 0..m {
                        acc += x[j * m + cc] as f64 * gx[j * m + cc] as f64;
                    }
                    // SAFETY: workers receive disjoint ranges of gc.
                    unsafe {
                        let o = ptr.get().add(j);
                        *o = (*o as f64 + acc) as f32;
                    }
                }
            });
        }
        Ok(())
    }
}

/// Full finetuning: the adapter *is* the replacement weight matrix.
pub struct FullOp;

impl TransformOp for FullOp {
    fn kind(&self) -> MethodKind {
        MethodKind::Full
    }

    fn token(&self) -> &'static str {
        "full"
    }

    fn arity(&self) -> Arity {
        Arity::Fixed
    }

    fn spec_name(&self, _spec: &MethodSpec) -> String {
        "full".into()
    }

    fn param_schema(&self, _spec: &MethodSpec, d: usize, f: usize) -> Vec<(&'static str, Vec<usize>)> {
        vec![("w", vec![d, f])]
    }

    fn apply_blocked(&self, _spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        Ok(Mat::from_vec(w.rows, w.cols, p.get("w").to_vec()))
    }

    fn apply_serial(&self, spec: &MethodSpec, p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        self.apply_blocked(spec, p, w)
    }

    fn apply_into(
        &self,
        _spec: &MethodSpec,
        p: &ResolvedParams,
        _src: &[f32],
        _d: usize,
        _f: usize,
        out: &mut [f32],
    ) {
        out.copy_from_slice(p.get("w"));
    }

    fn supports_activations(&self) -> bool {
        true
    }

    /// The adapter *is* the weight matrix: one product with it.
    fn apply_activations_into(
        &self,
        _spec: &MethodSpec,
        p: &ResolvedParams,
        _w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        tf::matmul_tiled_into(p.get("w"), x, d, f, m, out);
        Ok(())
    }

    /// Affine factors: `T(M) = 0·M + P` — the left factor annihilates
    /// whatever is beneath it in a stack, and `Δ = P·x` replaces it.
    fn supports_composition(&self) -> bool {
        true
    }

    fn act_left_into(
        &self,
        _spec: &MethodSpec,
        _p: &ResolvedParams,
        _y: &[f32],
        _shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        out.fill(0.0);
        Ok(())
    }

    fn act_delta_acc(
        &self,
        _spec: &MethodSpec,
        p: &ResolvedParams,
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        tf::matmul_acc_into(p.get("w"), x, d, f, m, out);
        Ok(())
    }

    fn supports_grad(&self) -> bool {
        true
    }

    /// The adapter *is* the weight matrix: `∂L/∂P = g·xᵀ` — the frozen
    /// base never enters the gradient.
    fn grad_params_into(
        &self,
        _spec: &MethodSpec,
        _p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        upstream: &[f32],
        shape: ActShape,
        threads: Option<usize>,
        grad: &mut GradParams,
    ) -> Result<()> {
        ensure_grad_shapes(self, w, x, upstream, shape)?;
        let ActShape { d, f, m } = shape;
        let gw = grad.get("w");
        let ptr = SendPtr::new(gw.as_mut_ptr());
        parallel_for_chunks_opt(threads, d, 16, |r0, r1| {
            ptr.claim(r0 * f, (r1 - r0) * f);
            for i in r0..r1 {
                // SAFETY: workers receive disjoint row ranges of gw.
                let out = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(i * f), f) };
                for (j, o) in out.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for c in 0..m {
                        acc += upstream[i * m + c] as f64 * x[j * m + c] as f64;
                    }
                    *o = (*o as f64 + acc) as f32;
                }
            }
        });
        Ok(())
    }
}

/// `none`: the frozen base model — merge is a pass-through.
pub struct NoneOp;

impl TransformOp for NoneOp {
    fn kind(&self) -> MethodKind {
        MethodKind::None
    }

    fn token(&self) -> &'static str {
        "none"
    }

    fn arity(&self) -> Arity {
        Arity::Fixed
    }

    fn spec_name(&self, _spec: &MethodSpec) -> String {
        "none".into()
    }

    fn is_identity(&self) -> bool {
        true
    }

    /// The identity is trivially its own inverse.
    fn supports_unmerge(&self) -> bool {
        true
    }

    fn param_schema(&self, _spec: &MethodSpec, _d: usize, _f: usize) -> Vec<(&'static str, Vec<usize>)> {
        vec![]
    }

    fn apply_blocked(&self, _spec: &MethodSpec, _p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        Ok(w.clone())
    }

    fn apply_serial(&self, _spec: &MethodSpec, _p: &ResolvedParams, w: &Mat) -> Result<Mat> {
        Ok(w.clone())
    }

    fn apply_into(
        &self,
        _spec: &MethodSpec,
        _p: &ResolvedParams,
        src: &[f32],
        _d: usize,
        _f: usize,
        out: &mut [f32],
    ) {
        out.copy_from_slice(src);
    }

    fn unmerge_into(
        &self,
        _spec: &MethodSpec,
        _p: &ResolvedParams,
        merged: &[f32],
        _d: usize,
        _f: usize,
        out: &mut [f32],
    ) -> Result<()> {
        out.copy_from_slice(merged);
        Ok(())
    }

    fn supports_activations(&self) -> bool {
        true
    }

    /// The frozen base forward.
    fn apply_activations_into(
        &self,
        _spec: &MethodSpec,
        _p: &ResolvedParams,
        w: &[f32],
        x: &[f32],
        shape: ActShape,
        out: &mut [f32],
    ) -> Result<()> {
        let ActShape { d, f, m } = shape;
        tf::matmul_tiled_into(w, x, d, f, m, out);
        Ok(())
    }

    /// Affine factors: the identity (`L = R = I`, `Δ = 0`) — every hook
    /// default is already correct.
    fn supports_composition(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn params_for<'a>(fields: Vec<(&'static str, &'a [f32])>) -> ResolvedParams<'a> {
        ResolvedParams { fields }
    }

    #[test]
    fn woodbury_inverts_relaxed_reflection() {
        // y = (I − ûûᵀ + v̂v̂ᵀ) x, then the Woodbury solve recovers x.
        let mut rng = Rng::new(3);
        let (d, f, n) = (16, 5, 2);
        let u = tf::normalize_blocks(&rng.normal_vec(d, 1.0), n);
        let mut v = tf::normalize_blocks(&rng.normal_vec(d, 1.0), n);
        // Keep û·v̂ away from zero so every block stays invertible.
        for (vi, ui) in v.iter_mut().zip(&u) {
            *vi = 0.7 * *vi + 0.7 * *ui;
        }
        let v = tf::normalize_blocks(&v, n);
        let x: Vec<f32> = rng.normal_vec(d * f, 1.0);
        let mut y = vec![0.0f32; d * f];
        tf::ether_plus_left_into(&u, &v, n, &x, f, &mut y);
        let mut back = vec![0.0f32; d * f];
        ether_plus_left_uninto(&u, &v, n, &y, f, &mut back).unwrap();
        let err = x.iter().zip(&back).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err < 1e-5, "woodbury roundtrip error {err}");
    }

    #[test]
    fn woodbury_rejects_orthogonal_pair() {
        // û ⊥ v̂ makes the relaxed reflection singular (H⁺û = 0).
        let u = [1.0f32, 0.0, 0.0, 0.0];
        let v = [0.0f32, 1.0, 0.0, 0.0];
        assert!(woodbury_2x2(&u, &v).is_err());
    }

    #[test]
    fn delora_update_is_normalized_and_signed() {
        let mut rng = Rng::new(9);
        let (d, r, f) = (8, 2, 6);
        let a: Vec<f32> = rng.normal_vec(d * r, 1.0);
        let b: Vec<f32> = rng.normal_vec(r * f, 1.0);
        let sa = delora_scaled_a(&a, &b, 2.0, d, r, f, 1.0);
        let nsa = delora_scaled_a(&a, &b, 2.0, d, r, f, -1.0);
        for (x, y) in sa.iter().zip(&nsa) {
            assert_eq!(*x, -*y);
        }
        // ‖scaled_a_t‖·‖b_t‖ == λ/r for every component.
        for t in 0..r {
            let na: f64 = (0..d).map(|i| (sa[i * r + t] as f64).powi(2)).sum::<f64>().sqrt();
            let nb: f64 = (0..f).map(|c| (b[t * f + c] as f64).powi(2)).sum::<f64>().sqrt();
            assert!((na * nb - 2.0 / r as f64).abs() < 1e-6, "component {t}: {}", na * nb);
        }
    }

    #[test]
    fn activation_fast_paths_match_the_materialize_oracle() {
        // Every kind's merge-free activation kernel must agree with the
        // materialize-then-multiply oracle on one (d, f) slice. The
        // registry-wide sweep over whole models lives in
        // rust/tests/engine_parity.rs; this is the op-local unit.
        let mut rng = Rng::new(23);
        let (d, f, m) = (16usize, 12usize, 3usize);
        let w: Vec<f32> = rng.normal_vec(d * f, 0.1);
        let x: Vec<f32> = rng.normal_vec(f * m, 0.5);
        let shape = ActShape { d, f, m };

        // ETHER
        let spec = MethodSpec::parse("ether_n4").unwrap();
        let u: Vec<f32> = rng.normal_vec(d, 0.8);
        let p = params_for(vec![("u", &u[..])]);
        let fast = EtherOp.apply_activations(&spec, &p, &w, &x, shape).unwrap();
        let slow = EtherOp.apply_activations_serial(&spec, &p, &w, &x, shape).unwrap();
        let err = fast.iter().zip(&slow).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err <= 1e-5, "ether activation parity {err}");

        // Two-sided ETHER+ (the order-of-factors case).
        let spec = MethodSpec::parse("etherplus_n4").unwrap();
        let u: Vec<f32> = rng.normal_vec(d, 0.8);
        let v: Vec<f32> = rng.normal_vec(d, 0.8);
        let ru: Vec<f32> = rng.normal_vec(f, 0.8);
        let rv: Vec<f32> = rng.normal_vec(f, 0.8);
        let p = params_for(vec![("u", &u[..]), ("v", &v[..]), ("ru", &ru[..]), ("rv", &rv[..])]);
        let fast = EtherPlusOp.apply_activations(&spec, &p, &w, &x, shape).unwrap();
        let slow = EtherPlusOp.apply_activations_serial(&spec, &p, &w, &x, shape).unwrap();
        let err = fast.iter().zip(&slow).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err <= 1e-5, "etherplus activation parity {err}");

        // LoRA (the low-rank shortcut).
        let spec = MethodSpec::parse("lora_r3").unwrap();
        let a: Vec<f32> = rng.normal_vec(d * 3, 0.4);
        let b: Vec<f32> = rng.normal_vec(3 * f, 0.4);
        let p = params_for(vec![("a", &a[..]), ("b", &b[..])]);
        let fast = LoraOp.apply_activations(&spec, &p, &w, &x, shape).unwrap();
        let slow = LoraOp.apply_activations_serial(&spec, &p, &w, &x, shape).unwrap();
        let err = fast.iter().zip(&slow).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err <= 1e-5, "lora activation parity {err}");

        // VeRA stays unsupported (and says so).
        assert!(!VeraOp.supports_activations());
        assert!(VeraOp.apply_activations(&spec, &p, &w, &x, shape).is_err());
    }

    #[test]
    fn lora_grad_matches_dense_reference() {
        // ∂L/∂A = g·(B·x)ᵀ and ∂L/∂B = (Aᵀ·g)·xᵀ, checked against
        // dense Mat products (the full FD harness lives in
        // rust/tests/grad_props.rs; this is the op-local unit).
        let mut rng = Rng::new(31);
        let (d, f, m, r) = (12usize, 10usize, 3usize, 2usize);
        let spec = MethodSpec::parse("lora_r2").unwrap();
        let a: Vec<f32> = rng.normal_vec(d * r, 0.5);
        let b: Vec<f32> = rng.normal_vec(r * f, 0.5);
        let w: Vec<f32> = rng.normal_vec(d * f, 0.1);
        let x: Vec<f32> = rng.normal_vec(f * m, 1.0);
        let g: Vec<f32> = rng.normal_vec(d * m, 1.0);
        let p = params_for(vec![("a", &a[..]), ("b", &b[..])]);
        let mut ga = vec![0.0f32; d * r];
        let mut gb = vec![0.0f32; r * f];
        {
            let mut gp = GradParams::from_fields(vec![("a", &mut ga[..]), ("b", &mut gb[..])]);
            LoraOp
                .grad_params_into(&spec, &p, &w, &x, &g, ActShape { d, f, m }, None, &mut gp)
                .unwrap();
        }
        let gm = Mat::from_vec(d, m, g.clone());
        let xm = Mat::from_vec(f, m, x.clone());
        let am = Mat::from_vec(d, r, a.clone());
        let bm = Mat::from_vec(r, f, b.clone());
        let want_ga = gm.matmul(&bm.matmul(&xm).transpose());
        let want_gb = am.transpose().matmul(&gm).matmul(&xm.transpose());
        let err_a =
            ga.iter().zip(&want_ga.data).map(|(p, q)| (p - q).abs()).fold(0.0f32, f32::max);
        let err_b =
            gb.iter().zip(&want_gb.data).map(|(p, q)| (p - q).abs()).fold(0.0f32, f32::max);
        assert!(err_a <= 1e-5, "lora ∂A parity {err_a}");
        assert!(err_b <= 1e-5, "lora ∂B parity {err_b}");
    }

    #[test]
    fn grads_accumulate_and_unsupported_ops_bail() {
        let mut rng = Rng::new(32);
        let (d, f, m) = (8usize, 6usize, 2usize);
        let spec = MethodSpec::parse("ether_n2").unwrap();
        let u: Vec<f32> = rng.normal_vec(d, 1.0);
        let w: Vec<f32> = rng.normal_vec(d * f, 0.1);
        let x: Vec<f32> = rng.normal_vec(f * m, 1.0);
        let g: Vec<f32> = rng.normal_vec(d * m, 1.0);
        let p = params_for(vec![("u", &u[..])]);
        let shape = ActShape { d, f, m };
        let mut once = vec![0.0f32; d];
        {
            let mut gp = GradParams::from_fields(vec![("u", &mut once[..])]);
            EtherOp.grad_params_into(&spec, &p, &w, &x, &g, shape, Some(1), &mut gp).unwrap();
        }
        // Gradients accumulate: two identical calls double the result.
        let mut twice = vec![0.0f32; d];
        {
            let mut gp = GradParams::from_fields(vec![("u", &mut twice[..])]);
            EtherOp.grad_params_into(&spec, &p, &w, &x, &g, shape, Some(1), &mut gp).unwrap();
            EtherOp.grad_params_into(&spec, &p, &w, &x, &g, shape, Some(1), &mut gp).unwrap();
        }
        for (o, t) in once.iter().zip(&twice) {
            assert!((2.0 * o - t).abs() <= 1e-5 * t.abs().max(1.0), "{o} vs {t}");
        }
        assert!(once.iter().any(|v| v.abs() > 1e-6), "ether grad is all zero");
        // The identity has no parameters; VeRA is device-only — both
        // refuse the gradient surface.
        assert!(!NoneOp.supports_grad());
        assert!(!VeraOp.supports_grad());
        let mut empty = GradParams::from_fields(vec![]);
        assert!(NoneOp
            .grad_params_into(&spec, &p, &w, &x, &g, shape, None, &mut empty)
            .is_err());
    }

    #[test]
    fn delora_roundtrip_subtracts_exactly_enough() {
        let mut rng = Rng::new(11);
        let (d, r, f) = (12, 3, 7);
        let spec = MethodSpec::parse("delora_r3").unwrap();
        let a: Vec<f32> = rng.normal_vec(d * r, 0.5);
        let b: Vec<f32> = rng.normal_vec(r * f, 0.5);
        let lambda = [0.8f32];
        let w: Vec<f32> = rng.normal_vec(d * f, 0.1);
        let p = params_for(vec![("a", &a[..]), ("b", &b[..]), ("lambda", &lambda[..])]);
        let mut merged = vec![0.0f32; d * f];
        DeloraOp.apply_into(&spec, &p, &w, d, f, &mut merged);
        let moved = w.iter().zip(&merged).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(moved > 1e-4, "delora update did nothing");
        let mut back = vec![0.0f32; d * f];
        DeloraOp.unmerge_into(&spec, &p, &merged, d, f, &mut back).unwrap();
        let err = w.iter().zip(&back).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(err < 1e-5, "delora unmerge error {err}");
    }
}
