//! Paged on-disk adapter-parameter store.
//!
//! ETHER adapters are tiny (one reflection vector per adapted matrix —
//! 10–100× fewer parameters than LoRA, PAPER.md §1), which is what makes
//! a *million*-adapter fleet plausible: at ~KBs per adapter the params
//! fit on disk trivially, and only the working set needs to be resident.
//! This module is that spill tier. Cold adapter params live in a single
//! **page file**; an in-memory index maps adapter id → (page, offset,
//! length, checksum), and a small LRU cache of whole pages absorbs the
//! zipf head so the resident footprint is `O(cache_pages × page_bytes)`
//! regardless of how many adapters exist.
//!
//! Layout: records are appended into the current **open page** (an
//! in-memory buffer). When a record no longer fits, the open page is
//! sealed — padded to `page_bytes`, written at `page_no × page_bytes`,
//! counted as a **page-out** — and a fresh page opens. Reads hit, in
//! order: the open page, the page cache, and finally the disk (counted
//! as a **page-in**). Every record carries an FNV-1a checksum verified
//! on read.
//!
//! Failure policy: **errors, never panics**. A short read (truncated
//! file), a checksum mismatch (bit rot / external corruption), an
//! unknown id, or a record larger than a page all surface as `Err`.
//!
//! Non-goals (documented trade-offs): the page file is ephemeral spill
//! space, re-created on open; re-`put`ting an id leaks the old record's
//! bytes (the index just points at the new copy); `flush` seals a
//! partially-filled page, wasting its tail. All fine at KB-sized records.

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::rng::hash64;

/// Store geometry. Defaults match the `ETHER_STORE_PAGE_KB` /
/// `ETHER_STORE_CACHE_PAGES` knob defaults (64 KiB pages, 8 cached).
#[derive(Clone, Debug)]
pub struct StoreCfg {
    /// Path of the page file itself (parent directories are created).
    pub path: PathBuf,
    /// Page size in bytes; every record must fit in one page.
    pub page_bytes: usize,
    /// LRU page-cache capacity, in pages.
    pub cache_pages: usize,
}

impl StoreCfg {
    pub fn new(path: impl Into<PathBuf>) -> StoreCfg {
        StoreCfg { path: path.into(), page_bytes: 64 * 1024, cache_pages: 8 }
    }

    pub fn page_bytes(mut self, n: usize) -> StoreCfg {
        self.page_bytes = n.max(64);
        self
    }

    pub fn cache_pages(mut self, n: usize) -> StoreCfg {
        self.cache_pages = n.max(1);
        self
    }
}

/// One adapter's params + identity as stored. The registry wraps this
/// into its own entry type; the store itself stays independent of the
/// serving layer.
#[derive(Clone, Debug, PartialEq)]
pub struct AdapterRecord {
    pub id: String,
    pub method: String,
    pub cfg: String,
    pub params: Vec<f32>,
}

/// Paging / caching counters plus the resident footprint, all taken
/// under one lock so the numbers are mutually consistent.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StoreStats {
    /// Records currently indexed.
    pub records: usize,
    /// Pages sealed to disk so far.
    pub pages: u64,
    /// Whole-page reads from disk (cold misses).
    pub page_ins: u64,
    /// Whole-page writes to disk (seals).
    pub page_outs: u64,
    /// Reads served from the open page or the page cache.
    pub cache_hits: u64,
    /// Reads that had to go to disk.
    pub cache_misses: u64,
    /// Bytes held in memory right now (open page + cached pages).
    pub resident_bytes: usize,
}

#[derive(Clone, Debug)]
struct RecordMeta {
    page: u64,
    off: usize,
    nbytes: usize,
    checksum: u64,
    method: String,
    cfg: String,
}

struct Inner {
    file: std::fs::File,
    index: HashMap<String, RecordMeta>,
    /// Page number of the in-memory open page.
    open_page: u64,
    open_buf: Vec<u8>,
    /// LRU page cache: back = most recently used.
    cache: Vec<(u64, Arc<Vec<u8>>)>,
    page_ins: u64,
    page_outs: u64,
    cache_hits: u64,
    cache_misses: u64,
}

/// Thread-safe paged adapter store (share via `Arc`). See the module
/// docs for the layout and failure policy.
pub struct PagedStore {
    cfg: StoreCfg,
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for PagedStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedStore")
            .field("path", &self.cfg.path)
            .field("stats", &self.stats())
            .finish()
    }
}

impl PagedStore {
    /// Create (truncating any previous file at `cfg.path` — the store is
    /// ephemeral spill space, not a durable database).
    pub fn create(cfg: StoreCfg) -> Result<PagedStore> {
        if let Some(parent) = cfg.path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating store dir {parent:?}"))?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&cfg.path)
            .with_context(|| format!("opening page file {:?}", cfg.path))?;
        Ok(PagedStore {
            inner: Mutex::new(Inner {
                file,
                index: HashMap::new(),
                open_page: 0,
                open_buf: Vec::with_capacity(cfg.page_bytes),
                cache: Vec::new(),
                page_ins: 0,
                page_outs: 0,
                cache_hits: 0,
                cache_misses: 0,
            }),
            cfg,
        })
    }

    pub fn path(&self) -> &Path {
        &self.cfg.path
    }

    /// Append one adapter's params. Errors if the record cannot fit in a
    /// single page. Re-putting an id replaces its index entry (the old
    /// bytes leak — documented trade-off).
    pub fn put(&self, id: &str, method: &str, cfg: &str, params: &[f32]) -> Result<()> {
        let nbytes = params.len() * 4;
        if nbytes > self.cfg.page_bytes {
            bail!(
                "adapter {id:?} is {nbytes} B but the store page is {} B — \
                 raise ETHER_STORE_PAGE_KB",
                self.cfg.page_bytes
            );
        }
        let mut g = self.lock();
        if g.open_buf.len() + nbytes > self.cfg.page_bytes {
            self.seal_open(&mut g)?;
        }
        let off = g.open_buf.len();
        for v in params {
            g.open_buf.extend_from_slice(&v.to_le_bytes());
        }
        let checksum = hash64(&g.open_buf[off..off + nbytes]);
        let meta = RecordMeta {
            page: g.open_page,
            off,
            nbytes,
            checksum,
            method: method.to_string(),
            cfg: cfg.to_string(),
        };
        g.index.insert(id.to_string(), meta);
        Ok(())
    }

    /// Read one adapter back, verifying its checksum. Every failure mode
    /// — unknown id, short read, out-of-bounds record, checksum mismatch
    /// — is an `Err`, never a panic.
    pub fn get(&self, id: &str) -> Result<AdapterRecord> {
        let mut g = self.lock();
        let meta = g
            .index
            .get(id)
            .cloned()
            .ok_or_else(|| anyhow!("unknown adapter {id:?} in store {:?}", self.cfg.path))?;
        let bytes: Vec<u8> = if meta.page == g.open_page {
            g.cache_hits += 1;
            if meta.off + meta.nbytes > g.open_buf.len() {
                bail!("corrupt store index: {id:?} points past the open page");
            }
            g.open_buf[meta.off..meta.off + meta.nbytes].to_vec()
        } else {
            let page = self.page_for(&mut g, meta.page)?;
            if meta.off + meta.nbytes > page.len() {
                bail!("corrupt store: record {id:?} out of page bounds");
            }
            page[meta.off..meta.off + meta.nbytes].to_vec()
        };
        if hash64(&bytes) != meta.checksum {
            bail!("corrupt store: checksum mismatch reading adapter {id:?}");
        }
        let params: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(AdapterRecord { id: id.to_string(), method: meta.method, cfg: meta.cfg, params })
    }

    pub fn contains(&self, id: &str) -> bool {
        self.lock().index.contains_key(id)
    }

    /// Number of adapters indexed.
    pub fn len(&self) -> usize {
        self.lock().index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total f32 params across all indexed records.
    pub fn total_params(&self) -> usize {
        self.lock().index.values().map(|m| m.nbytes / 4).sum()
    }

    /// Seal the open page to disk (even partially filled). After a flush
    /// every record is durable in the page file; subsequent puts open a
    /// fresh page.
    pub fn flush(&self) -> Result<()> {
        let mut g = self.lock();
        self.seal_open(&mut g)
    }

    /// Drop the in-memory page cache (the open page stays). With
    /// `flush()` first, this forces the next `get` of every record to
    /// page in from disk — used by parity tests and cold-start probes.
    pub fn drop_caches(&self) {
        self.lock().cache.clear();
    }

    pub fn stats(&self) -> StoreStats {
        let g = self.lock();
        StoreStats {
            records: g.index.len(),
            pages: g.open_page,
            page_ins: g.page_ins,
            page_outs: g.page_outs,
            cache_hits: g.cache_hits,
            cache_misses: g.cache_misses,
            resident_bytes: g.open_buf.len() + g.cache.iter().map(|(_, p)| p.len()).sum::<usize>(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn seal_open(&self, g: &mut Inner) -> Result<()> {
        if g.open_buf.is_empty() {
            return Ok(());
        }
        g.open_buf.resize(self.cfg.page_bytes, 0);
        let pos = g.open_page * self.cfg.page_bytes as u64;
        let page = std::mem::replace(&mut g.open_buf, Vec::with_capacity(self.cfg.page_bytes));
        g.file
            .seek(SeekFrom::Start(pos))
            .and_then(|_| g.file.write_all(&page))
            .and_then(|_| g.file.flush())
            .with_context(|| format!("sealing page {} to {:?}", g.open_page, self.cfg.path))?;
        g.page_outs += 1;
        let sealed_no = g.open_page;
        self.cache_insert(g, sealed_no, Arc::new(page));
        g.open_page += 1;
        Ok(())
    }

    /// Fetch a sealed page: cache hit (LRU-touched) or disk page-in.
    fn page_for(&self, g: &mut Inner, page_no: u64) -> Result<Arc<Vec<u8>>> {
        if let Some(i) = g.cache.iter().position(|(no, _)| *no == page_no) {
            let hit = g.cache.remove(i);
            let page = hit.1.clone();
            g.cache.push(hit);
            g.cache_hits += 1;
            return Ok(page);
        }
        g.cache_misses += 1;
        let mut buf = vec![0u8; self.cfg.page_bytes];
        g.file
            .seek(SeekFrom::Start(page_no * self.cfg.page_bytes as u64))
            .and_then(|_| g.file.read_exact(&mut buf))
            .with_context(|| {
                format!("paging in page {page_no} from {:?} (short read?)", self.cfg.path)
            })?;
        g.page_ins += 1;
        let page = Arc::new(buf);
        self.cache_insert(g, page_no, page.clone());
        Ok(page)
    }

    fn cache_insert(&self, g: &mut Inner, page_no: u64, page: Arc<Vec<u8>>) {
        if let Some(i) = g.cache.iter().position(|(no, _)| *no == page_no) {
            g.cache.remove(i);
        }
        g.cache.push((page_no, page));
        while g.cache.len() > self.cfg.cache_pages {
            g.cache.remove(0); // evict LRU; pages are clean, nothing to write back
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("ether_store_{}_{name}", std::process::id()))
            .join("pages.bin")
    }

    fn small_store(name: &str) -> PagedStore {
        // 256-byte pages / 2 cached: evictions and seals happen fast.
        PagedStore::create(StoreCfg::new(tmp(name)).page_bytes(256).cache_pages(2)).unwrap()
    }

    #[test]
    fn put_get_roundtrip_across_pages() {
        let s = small_store("roundtrip");
        let mk = |i: usize| (0..32).map(|j| (i * 100 + j) as f32).collect::<Vec<f32>>();
        for i in 0..20 {
            s.put(&format!("u{i}"), "ether_n4", "host", &mk(i)).unwrap();
        }
        assert_eq!(s.len(), 20);
        // 32 f32 = 128 B → 2 records per 256 B page → 10 pages, 9 sealed.
        assert!(s.stats().page_outs >= 8, "{:?}", s.stats());
        for i in 0..20 {
            let r = s.get(&format!("u{i}")).unwrap();
            assert_eq!(r.params, mk(i));
            assert_eq!(r.method, "ether_n4");
            assert_eq!(r.cfg, "host");
        }
        // Far more sealed pages than the 2-page cache → some disk reads.
        assert!(s.stats().page_ins > 0, "{:?}", s.stats());
        assert_eq!(s.total_params(), 20 * 32);
    }

    #[test]
    fn flush_then_cold_read_pages_in() {
        let s = small_store("cold");
        s.put("a", "m", "c", &[1.0, 2.0, 3.0]).unwrap();
        s.flush().unwrap();
        s.drop_caches();
        let before = s.stats().page_ins;
        assert_eq!(s.get("a").unwrap().params, vec![1.0, 2.0, 3.0]);
        assert_eq!(s.stats().page_ins, before + 1);
    }

    #[test]
    fn unknown_id_is_err() {
        let s = small_store("unknown");
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn oversized_record_is_err() {
        let s = small_store("oversize");
        let big = vec![0.0f32; 1024]; // 4 KiB > 256 B page
        let e = s.put("big", "m", "c", &big).unwrap_err();
        assert!(e.to_string().contains("page"), "{e}");
    }

    #[test]
    fn corruption_is_err_not_panic() {
        let s = small_store("corrupt");
        s.put("a", "m", "c", &[5.0; 16]).unwrap();
        s.flush().unwrap();
        s.drop_caches();
        // Flip a byte in the record on disk through an independent handle.
        let path = s.path().to_path_buf();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[3] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let e = s.get("a").unwrap_err();
        assert!(e.to_string().contains("checksum"), "{e}");
    }

    #[test]
    fn short_read_is_err_not_panic() {
        let s = small_store("shortread");
        s.put("a", "m", "c", &[5.0; 16]).unwrap();
        s.flush().unwrap();
        s.drop_caches();
        // Truncate the file: the page-in read must fail cleanly.
        let f = std::fs::OpenOptions::new().write(true).open(s.path()).unwrap();
        f.set_len(10).unwrap();
        assert!(s.get("a").is_err());
    }

    #[test]
    fn reput_replaces() {
        let s = small_store("reput");
        s.put("a", "m", "c", &[1.0]).unwrap();
        s.put("a", "m", "c", &[2.0, 3.0]).unwrap();
        assert_eq!(s.get("a").unwrap().params, vec![2.0, 3.0]);
        assert_eq!(s.len(), 1);
        assert_eq!(s.total_params(), 2);
    }

    #[test]
    fn resident_bytes_bounded_by_cache() {
        let s = small_store("bounded");
        for i in 0..200 {
            s.put(&format!("u{i}"), "m", "c", &[i as f32; 16]).unwrap();
        }
        for i in 0..200 {
            s.get(&format!("u{i}")).unwrap();
        }
        // open page + 2 cached pages at 256 B each.
        assert!(s.stats().resident_bytes <= 3 * 256, "{:?}", s.stats());
    }
}
