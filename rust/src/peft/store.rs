//! Paged on-disk adapter-parameter store.
//!
//! ETHER adapters are tiny (one reflection vector per adapted matrix —
//! 10–100× fewer parameters than LoRA, PAPER.md §1), which is what makes
//! a *million*-adapter fleet plausible: at ~KBs per adapter the params
//! fit on disk trivially, and only the working set needs to be resident.
//! This module is that spill tier. Cold adapter params live in a single
//! **page file**; an in-memory index maps adapter id → (page, offset,
//! length, checksum), and a small LRU cache of whole pages absorbs the
//! zipf head so the resident footprint is `O(cache_pages × page_bytes)`
//! regardless of how many adapters exist.
//!
//! Layout: each record is self-describing on disk — a fixed
//! [`HEADER_BYTES`] header (magic, string lengths, payload length, FNV-1a
//! payload checksum) followed by the id / method / cfg strings and the
//! raw little-endian f32 payload. Records are appended into the current
//! **open page** (an in-memory buffer) and never span pages. When a
//! record no longer fits, the open page is sealed — padded to
//! `page_bytes`, written at `page_no × page_bytes`, counted as a
//! **page-out** — and a fresh page opens. Reads hit, in order: the open
//! page, the page cache, and finally the disk (counted as a **page-in**).
//! The checksum is verified on every read.
//!
//! Durability: [`PagedStore::create`] truncates (fresh spill space);
//! [`PagedStore::open`] instead **recovers** an existing page file by
//! scanning record headers page by page — every fully-written record is
//! re-indexed (later copies of an id win, since file order is append
//! order), a torn tail record from a crash mid-write is dropped, and the
//! torn tail is padded back to page alignment so subsequent page-ins
//! read cleanly.
//!
//! Space: re-`put`ting an id appends a fresh copy and the old record's
//! bytes become **dead** (tracked in [`StoreStats::dead_bytes`]).
//! [`PagedStore::compact`] rewrites live records into a fresh page file
//! (temp file + atomic rename) and reclaims them; `put` triggers it
//! automatically once dead bytes exceed [`StoreCfg::compact_ratio`] of
//! the file's record bytes.
//!
//! Failure policy: **errors, never panics**. A short read (truncated
//! file), a checksum mismatch (bit rot / external corruption), an
//! unknown id, or a record larger than a page all surface as `Err`.

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::rng::hash64;

/// Record header: `[magic u32][id_len u16][method_len u16][cfg_len u16]
/// [reserved u16][nbytes u32][checksum u64]`, all little-endian. The
/// checksum covers the payload bytes only.
const HEADER_BYTES: usize = 24;
const RECORD_MAGIC: u32 = 0x4554_4852; // "ETHR"

/// Store geometry. Defaults match the `ETHER_STORE_PAGE_KB` /
/// `ETHER_STORE_CACHE_PAGES` knob defaults (64 KiB pages, 8 cached).
#[derive(Clone, Debug)]
pub struct StoreCfg {
    /// Path of the page file itself (parent directories are created).
    pub path: PathBuf,
    /// Page size in bytes; every framed record must fit in one page.
    pub page_bytes: usize,
    /// LRU page-cache capacity, in pages.
    pub cache_pages: usize,
    /// Auto-compaction trigger: when `dead_bytes / (dead + live)` on a
    /// `put` reaches this ratio, the store compacts itself. Values
    /// outside `(0, 1)` disable auto-compaction (`compact()` still works
    /// explicitly). Default 0.5 — the file never exceeds ~2× its live
    /// bytes (rounded up to whole pages).
    pub compact_ratio: f64,
}

impl StoreCfg {
    pub fn new(path: impl Into<PathBuf>) -> StoreCfg {
        StoreCfg { path: path.into(), page_bytes: 64 * 1024, cache_pages: 8, compact_ratio: 0.5 }
    }

    pub fn page_bytes(mut self, n: usize) -> StoreCfg {
        self.page_bytes = n.max(64);
        self
    }

    pub fn cache_pages(mut self, n: usize) -> StoreCfg {
        self.cache_pages = n.max(1);
        self
    }

    pub fn compact_ratio(mut self, r: f64) -> StoreCfg {
        self.compact_ratio = r;
        self
    }

    /// Size pages to fit one framed record of `n_elems` payload elements
    /// at `bytes_per_elem` (4 for f32 params, or
    /// [`MergedPrecision::bytes_per_elem`](crate::peft::precision::MergedPrecision::bytes_per_elem)
    /// when the payload is a reduced-precision buffer): header + a
    /// string allowance + payload, rounded up to a power of two. Keeps
    /// the storage-precision choice and the page geometry in one place —
    /// halving the payload width (bf16) drops the page size a full
    /// power of two at most record shapes.
    pub fn fit_record(mut self, n_elems: usize, bytes_per_elem: usize) -> StoreCfg {
        /// Generous bound on `id`+`method`+`cfg` string bytes per record.
        const STRING_ALLOWANCE: usize = 192;
        let framed = HEADER_BYTES + STRING_ALLOWANCE + n_elems * bytes_per_elem;
        self.page_bytes = framed.next_power_of_two().max(64);
        self
    }
}

/// One adapter's params + identity as stored. The registry wraps this
/// into its own entry type; the store itself stays independent of the
/// serving layer.
#[derive(Clone, Debug, PartialEq)]
pub struct AdapterRecord {
    pub id: String,
    pub method: String,
    pub cfg: String,
    pub params: Vec<f32>,
}

/// Paging / caching counters plus the resident footprint, all taken
/// under one lock so the numbers are mutually consistent.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StoreStats {
    /// Records currently indexed.
    pub records: usize,
    /// Pages sealed to disk so far.
    pub pages: u64,
    /// Whole-page reads from disk (cold misses).
    pub page_ins: u64,
    /// Whole-page writes to disk (seals).
    pub page_outs: u64,
    /// Reads served from the open page or the page cache.
    pub cache_hits: u64,
    /// Reads that had to go to disk.
    pub cache_misses: u64,
    /// Bytes held in memory right now (open page + cached pages).
    pub resident_bytes: usize,
    /// Framed bytes of live (indexed) records in the page file.
    pub live_bytes: usize,
    /// Framed bytes of overwritten records still occupying the page
    /// file; reclaimed by [`PagedStore::compact`].
    pub dead_bytes: usize,
    /// Compaction passes run (explicit or ratio-triggered).
    pub compactions: u64,
}

#[derive(Clone, Debug)]
struct RecordMeta {
    page: u64,
    /// Payload offset within the page (past the header and strings).
    off: usize,
    nbytes: usize,
    checksum: u64,
    method: String,
    cfg: String,
}

impl RecordMeta {
    /// On-disk footprint of the whole record, framing included.
    fn framed(&self, id: &str) -> usize {
        HEADER_BYTES + id.len() + self.method.len() + self.cfg.len() + self.nbytes
    }
}

fn framed_len(id: &str, method: &str, cfg: &str, nbytes: usize) -> usize {
    HEADER_BYTES + id.len() + method.len() + cfg.len() + nbytes
}

/// Append header + strings for one record (payload follows separately).
fn encode_record_prefix(
    buf: &mut Vec<u8>,
    id: &str,
    method: &str,
    cfg: &str,
    nbytes: usize,
    checksum: u64,
) {
    buf.extend_from_slice(&RECORD_MAGIC.to_le_bytes());
    buf.extend_from_slice(&(id.len() as u16).to_le_bytes());
    buf.extend_from_slice(&(method.len() as u16).to_le_bytes());
    buf.extend_from_slice(&(cfg.len() as u16).to_le_bytes());
    buf.extend_from_slice(&0u16.to_le_bytes()); // reserved
    buf.extend_from_slice(&(nbytes as u32).to_le_bytes());
    buf.extend_from_slice(&checksum.to_le_bytes());
    buf.extend_from_slice(id.as_bytes());
    buf.extend_from_slice(method.as_bytes());
    buf.extend_from_slice(cfg.as_bytes());
}

/// Scan one page region for framed records, indexing every valid one.
/// Later copies of an id win (file order is append order, so the last
/// copy is the freshest); overridden copies are counted as dead bytes.
/// Scanning a page stops at the first hole — zeroed seal padding, a
/// torn record extending past the region, a mangled string, or a
/// checksum mismatch — but records never span pages, so the next page
/// scans independently.
fn scan_page(
    region: &[u8],
    page_no: u64,
    index: &mut HashMap<String, RecordMeta>,
    live_bytes: &mut usize,
    dead_bytes: &mut usize,
) {
    let mut off = 0usize;
    while off + HEADER_BYTES <= region.len() {
        // Header reads stay in bounds (the loop guard holds
        // `off + HEADER_BYTES <= region.len()`), so these never slice
        // past the region; fixed-width copies avoid fallible casts.
        let word4 = |at: usize| {
            let mut b = [0u8; 4];
            b.copy_from_slice(&region[at..at + 4]);
            u32::from_le_bytes(b)
        };
        let word2 = |at: usize| {
            let mut b = [0u8; 2];
            b.copy_from_slice(&region[at..at + 2]);
            u16::from_le_bytes(b)
        };
        if word4(off) != RECORD_MAGIC {
            break;
        }
        let id_len = word2(off + 4) as usize;
        let method_len = word2(off + 6) as usize;
        let cfg_len = word2(off + 8) as usize;
        let nbytes = word4(off + 12) as usize;
        let checksum = {
            let mut b = [0u8; 8];
            b.copy_from_slice(&region[off + 16..off + 24]);
            u64::from_le_bytes(b)
        };
        let total = HEADER_BYTES + id_len + method_len + cfg_len + nbytes;
        if off + total > region.len() {
            break; // torn write: the record was never fully persisted
        }
        let sb = off + HEADER_BYTES;
        let id_end = sb + id_len;
        let method_end = id_end + method_len;
        let payload_off = method_end + cfg_len;
        let strings = (
            std::str::from_utf8(&region[sb..id_end]),
            std::str::from_utf8(&region[id_end..method_end]),
            std::str::from_utf8(&region[method_end..payload_off]),
        );
        let (Ok(id), Ok(method), Ok(cfg)) = strings else { break };
        if hash64(&region[payload_off..payload_off + nbytes]) != checksum {
            break;
        }
        let meta = RecordMeta {
            page: page_no,
            off: payload_off,
            nbytes,
            checksum,
            method: method.to_string(),
            cfg: cfg.to_string(),
        };
        if let Some(old) = index.insert(id.to_string(), meta) {
            let d = old.framed(id);
            *dead_bytes += d;
            *live_bytes -= d;
        }
        *live_bytes += total;
        off += total;
    }
}

struct Inner {
    file: std::fs::File,
    index: HashMap<String, RecordMeta>,
    /// Page number of the in-memory open page.
    open_page: u64,
    open_buf: Vec<u8>,
    /// LRU page cache: back = most recently used.
    cache: Vec<(u64, Arc<Vec<u8>>)>,
    page_ins: u64,
    page_outs: u64,
    cache_hits: u64,
    cache_misses: u64,
    live_bytes: usize,
    dead_bytes: usize,
    compactions: u64,
}

/// Thread-safe paged adapter store (share via `Arc`). See the module
/// docs for the layout and failure policy.
pub struct PagedStore {
    cfg: StoreCfg,
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for PagedStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedStore")
            .field("path", &self.cfg.path)
            .field("stats", &self.stats())
            .finish()
    }
}

impl PagedStore {
    /// Create fresh spill space, truncating any previous file at
    /// `cfg.path`. Use [`PagedStore::open`] to recover one instead.
    pub fn create(cfg: StoreCfg) -> Result<PagedStore> {
        let file = Self::open_file(&cfg, true)?;
        Ok(PagedStore { inner: Mutex::new(Self::fresh_inner(file, &cfg)), cfg })
    }

    /// Open an existing page file (or create an empty one), rebuilding
    /// the index by scanning record headers + checksums page by page.
    /// Every fully-written record is recovered; a torn tail from a crash
    /// mid-write is dropped and the file is padded back to page
    /// alignment. Recovered-but-overridden copies count as dead bytes.
    pub fn open(cfg: StoreCfg) -> Result<PagedStore> {
        let mut file = Self::open_file(&cfg, false)?;
        let file_len =
            file.metadata().with_context(|| format!("statting {:?}", cfg.path))?.len();
        let pb = cfg.page_bytes as u64;
        let full_pages = file_len / pb;
        let tail = (file_len % pb) as usize;

        let mut index = HashMap::new();
        let (mut live_bytes, mut dead_bytes) = (0usize, 0usize);
        let mut buf = vec![0u8; cfg.page_bytes];
        for page_no in 0..full_pages {
            file.seek(SeekFrom::Start(page_no * pb))
                .and_then(|_| file.read_exact(&mut buf))
                .with_context(|| format!("recovery: reading page {page_no} of {:?}", cfg.path))?;
            scan_page(&buf, page_no, &mut index, &mut live_bytes, &mut dead_bytes);
        }
        let mut open_page = full_pages;
        if tail > 0 {
            let mut tbuf = vec![0u8; tail];
            file.seek(SeekFrom::Start(full_pages * pb))
                .and_then(|_| file.read_exact(&mut tbuf))
                .with_context(|| format!("recovery: reading torn tail of {:?}", cfg.path))?;
            scan_page(&tbuf, full_pages, &mut index, &mut live_bytes, &mut dead_bytes);
            // Pad the torn tail back to page alignment so future
            // page-ins of this page read a full page cleanly.
            file.set_len((full_pages + 1) * pb)
                .with_context(|| format!("recovery: padding torn tail of {:?}", cfg.path))?;
            open_page = full_pages + 1;
        }

        let mut inner = Self::fresh_inner(file, &cfg);
        inner.index = index;
        inner.open_page = open_page;
        inner.live_bytes = live_bytes;
        inner.dead_bytes = dead_bytes;
        Ok(PagedStore { inner: Mutex::new(inner), cfg })
    }

    fn open_file(cfg: &StoreCfg, truncate: bool) -> Result<std::fs::File> {
        if let Some(parent) = cfg.path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating store dir {parent:?}"))?;
            }
        }
        std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(truncate)
            .open(&cfg.path)
            .with_context(|| format!("opening page file {:?}", cfg.path))
    }

    fn fresh_inner(file: std::fs::File, cfg: &StoreCfg) -> Inner {
        Inner {
            file,
            index: HashMap::new(),
            open_page: 0,
            open_buf: Vec::with_capacity(cfg.page_bytes),
            cache: Vec::new(),
            page_ins: 0,
            page_outs: 0,
            cache_hits: 0,
            cache_misses: 0,
            live_bytes: 0,
            dead_bytes: 0,
            compactions: 0,
        }
    }

    pub fn path(&self) -> &Path {
        &self.cfg.path
    }

    /// Append one adapter's params. Errors if the framed record cannot
    /// fit in a single page. Re-putting an id appends a fresh copy and
    /// retires the old one into the dead-bytes pool (auto-compacted at
    /// [`StoreCfg::compact_ratio`]).
    pub fn put(&self, id: &str, method: &str, cfg: &str, params: &[f32]) -> Result<()> {
        let nbytes = params.len() * 4;
        if id.len() > u16::MAX as usize
            || method.len() > u16::MAX as usize
            || cfg.len() > u16::MAX as usize
        {
            bail!("adapter {id:?}: id/method/cfg strings must each be under 64 KiB");
        }
        let framed = framed_len(id, method, cfg, nbytes);
        if framed > self.cfg.page_bytes {
            bail!(
                "adapter {id:?} is {framed} B framed ({nbytes} B params) but the store \
                 page is {} B — raise ETHER_STORE_PAGE_KB",
                self.cfg.page_bytes
            );
        }
        let mut g = self.lock();
        if g.open_buf.len() + framed > self.cfg.page_bytes {
            self.seal_open(&mut g)?;
        }
        let mut payload = Vec::with_capacity(nbytes);
        for v in params {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let checksum = hash64(&payload);
        let rec_off = g.open_buf.len();
        encode_record_prefix(&mut g.open_buf, id, method, cfg, nbytes, checksum);
        let off = rec_off + HEADER_BYTES + id.len() + method.len() + cfg.len();
        g.open_buf.extend_from_slice(&payload);
        let meta = RecordMeta {
            page: g.open_page,
            off,
            nbytes,
            checksum,
            method: method.to_string(),
            cfg: cfg.to_string(),
        };
        if let Some(old) = g.index.insert(id.to_string(), meta) {
            let d = old.framed(id);
            g.dead_bytes += d;
            g.live_bytes -= d;
        }
        g.live_bytes += framed;
        self.maybe_compact(&mut g)
    }

    /// Read one adapter back, verifying its checksum. Every failure mode
    /// — unknown id, short read, out-of-bounds record, checksum mismatch
    /// — is an `Err`, never a panic.
    pub fn get(&self, id: &str) -> Result<AdapterRecord> {
        let mut g = self.lock();
        let meta = g
            .index
            .get(id)
            .cloned()
            .ok_or_else(|| anyhow!("unknown adapter {id:?} in store {:?}", self.cfg.path))?;
        let bytes = self.read_payload(&mut g, id, &meta)?;
        let params: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(AdapterRecord { id: id.to_string(), method: meta.method, cfg: meta.cfg, params })
    }

    pub fn contains(&self, id: &str) -> bool {
        self.lock().index.contains_key(id)
    }

    /// Number of adapters indexed.
    pub fn len(&self) -> usize {
        self.lock().index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total f32 params across all indexed records.
    pub fn total_params(&self) -> usize {
        self.lock().index.values().map(|m| m.nbytes / 4).sum()
    }

    /// Seal the open page to disk (even partially filled). After a flush
    /// every record is durable in the page file; subsequent puts open a
    /// fresh page.
    pub fn flush(&self) -> Result<()> {
        let mut g = self.lock();
        self.seal_open(&mut g)
    }

    /// Drop the in-memory page cache (the open page stays). With
    /// `flush()` first, this forces the next `get` of every record to
    /// page in from disk — used by parity tests and cold-start probes.
    pub fn drop_caches(&self) {
        self.lock().cache.clear();
    }

    /// Rewrite the page file with only the live records (temp file +
    /// atomic rename), reclaiming all dead bytes. Records are re-packed
    /// in id order; every payload's checksum is re-verified on the way
    /// through, so compaction can never silently launder corruption.
    pub fn compact(&self) -> Result<()> {
        let mut g = self.lock();
        self.compact_locked(&mut g)
    }

    pub fn stats(&self) -> StoreStats {
        let g = self.lock();
        StoreStats {
            records: g.index.len(),
            pages: g.open_page,
            page_ins: g.page_ins,
            page_outs: g.page_outs,
            cache_hits: g.cache_hits,
            cache_misses: g.cache_misses,
            resident_bytes: g.open_buf.len() + g.cache.iter().map(|(_, p)| p.len()).sum::<usize>(),
            live_bytes: g.live_bytes,
            dead_bytes: g.dead_bytes,
            compactions: g.compactions,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Fetch + checksum-verify one record's payload bytes.
    fn read_payload(&self, g: &mut Inner, id: &str, meta: &RecordMeta) -> Result<Vec<u8>> {
        let bytes: Vec<u8> = if meta.page == g.open_page {
            g.cache_hits += 1;
            if meta.off + meta.nbytes > g.open_buf.len() {
                bail!("corrupt store index: {id:?} points past the open page");
            }
            g.open_buf[meta.off..meta.off + meta.nbytes].to_vec()
        } else {
            let page = self.page_for(g, meta.page)?;
            if meta.off + meta.nbytes > page.len() {
                bail!("corrupt store: record {id:?} out of page bounds");
            }
            page[meta.off..meta.off + meta.nbytes].to_vec()
        };
        if hash64(&bytes) != meta.checksum {
            bail!("corrupt store: checksum mismatch reading adapter {id:?}");
        }
        Ok(bytes)
    }

    fn maybe_compact(&self, g: &mut Inner) -> Result<()> {
        let r = self.cfg.compact_ratio;
        if !(r > 0.0 && r < 1.0) || g.dead_bytes == 0 {
            return Ok(());
        }
        let total = (g.dead_bytes + g.live_bytes) as f64;
        if (g.dead_bytes as f64) < r * total {
            return Ok(());
        }
        self.compact_locked(g)
    }

    fn compact_locked(&self, g: &mut Inner) -> Result<()> {
        // Snapshot (id, meta) pairs up front: `read_payload` needs `g`
        // mutably (page cache), so the index can't stay borrowed.
        let mut metas: Vec<(String, RecordMeta)> =
            g.index.iter().map(|(id, meta)| (id.clone(), meta.clone())).collect();
        metas.sort_by(|a, b| a.0.cmp(&b.0));
        let mut recs = Vec::with_capacity(metas.len());
        for (id, meta) in metas {
            let payload = self.read_payload(g, &id, &meta)?;
            recs.push((id, meta, payload));
        }

        let tmp = self.cfg.path.with_extension("compact");
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&tmp)
            .with_context(|| format!("opening compaction file {tmp:?}"))?;
        let mut index = HashMap::new();
        let mut live_bytes = 0usize;
        let mut buf: Vec<u8> = Vec::with_capacity(self.cfg.page_bytes);
        let mut page: u64 = 0;
        let mut pages_out: u64 = 0;
        let mut write_page = |file: &mut std::fs::File, buf: &mut Vec<u8>| -> Result<()> {
            buf.resize(self.cfg.page_bytes, 0);
            file.write_all(buf).with_context(|| format!("writing compaction page to {tmp:?}"))?;
            buf.clear();
            Ok(())
        };
        for (id, meta, payload) in recs {
            let framed = framed_len(&id, &meta.method, &meta.cfg, payload.len());
            if buf.len() + framed > self.cfg.page_bytes {
                write_page(&mut file, &mut buf)?;
                pages_out += 1;
                page += 1;
            }
            let off = buf.len() + HEADER_BYTES + id.len() + meta.method.len() + meta.cfg.len();
            encode_record_prefix(
                &mut buf,
                &id,
                &meta.method,
                &meta.cfg,
                payload.len(),
                meta.checksum,
            );
            buf.extend_from_slice(&payload);
            index.insert(id, RecordMeta { page, off, ..meta });
            live_bytes += framed;
        }
        if !buf.is_empty() {
            write_page(&mut file, &mut buf)?;
            pages_out += 1;
            page += 1;
        }
        file.flush().with_context(|| format!("flushing compaction file {tmp:?}"))?;
        std::fs::rename(&tmp, &self.cfg.path)
            .with_context(|| format!("renaming {tmp:?} over {:?}", self.cfg.path))?;

        // The renamed handle now backs cfg.path; swap all state over.
        g.file = file;
        g.index = index;
        g.open_page = page;
        g.open_buf.clear();
        g.cache.clear();
        g.live_bytes = live_bytes;
        g.dead_bytes = 0;
        g.page_outs += pages_out;
        g.compactions += 1;
        Ok(())
    }

    fn seal_open(&self, g: &mut Inner) -> Result<()> {
        if g.open_buf.is_empty() {
            return Ok(());
        }
        g.open_buf.resize(self.cfg.page_bytes, 0);
        let pos = g.open_page * self.cfg.page_bytes as u64;
        let page = std::mem::replace(&mut g.open_buf, Vec::with_capacity(self.cfg.page_bytes));
        g.file
            .seek(SeekFrom::Start(pos))
            .and_then(|_| g.file.write_all(&page))
            .and_then(|_| g.file.flush())
            .with_context(|| format!("sealing page {} to {:?}", g.open_page, self.cfg.path))?;
        g.page_outs += 1;
        let sealed_no = g.open_page;
        self.cache_insert(g, sealed_no, Arc::new(page));
        g.open_page += 1;
        Ok(())
    }

    /// Fetch a sealed page: cache hit (LRU-touched) or disk page-in.
    fn page_for(&self, g: &mut Inner, page_no: u64) -> Result<Arc<Vec<u8>>> {
        if let Some(i) = g.cache.iter().position(|(no, _)| *no == page_no) {
            let hit = g.cache.remove(i);
            let page = hit.1.clone();
            g.cache.push(hit);
            g.cache_hits += 1;
            return Ok(page);
        }
        g.cache_misses += 1;
        let mut buf = vec![0u8; self.cfg.page_bytes];
        g.file
            .seek(SeekFrom::Start(page_no * self.cfg.page_bytes as u64))
            .and_then(|_| g.file.read_exact(&mut buf))
            .with_context(|| {
                format!("paging in page {page_no} from {:?} (short read?)", self.cfg.path)
            })?;
        g.page_ins += 1;
        let page = Arc::new(buf);
        self.cache_insert(g, page_no, page.clone());
        Ok(page)
    }

    fn cache_insert(&self, g: &mut Inner, page_no: u64, page: Arc<Vec<u8>>) {
        if let Some(i) = g.cache.iter().position(|(no, _)| *no == page_no) {
            g.cache.remove(i);
        }
        g.cache.push((page_no, page));
        while g.cache.len() > self.cfg.cache_pages {
            g.cache.remove(0); // evict LRU; pages are clean, nothing to write back
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("ether_store_{}_{name}", std::process::id()))
            .join("pages.bin")
    }

    fn small_store(name: &str) -> PagedStore {
        // 256-byte pages / 2 cached: evictions and seals happen fast.
        PagedStore::create(StoreCfg::new(tmp(name)).page_bytes(256).cache_pages(2)).unwrap()
    }

    #[test]
    fn fit_record_pages_track_payload_width() {
        // 1024 f32 elements: 24 + 192 + 4096 B framed → 8 KiB pages.
        let full = StoreCfg::new(tmp("fit_f32")).fit_record(1024, 4);
        assert_eq!(full.page_bytes, 8192);
        // The same record at bf16 width halves into 4 KiB pages.
        let half = StoreCfg::new(tmp("fit_bf16")).fit_record(1024, 2);
        assert_eq!(half.page_bytes, 4096);
        // A record of exactly that shape actually fits.
        let s = PagedStore::create(full).unwrap();
        s.put("user0", "ether_n4", "host", &[0.5; 1024]).unwrap();
        assert_eq!(s.get("user0").unwrap().params.len(), 1024);
    }

    #[test]
    fn put_get_roundtrip_across_pages() {
        let s = small_store("roundtrip");
        let mk = |i: usize| (0..32).map(|j| (i * 100 + j) as f32).collect::<Vec<f32>>();
        for i in 0..20 {
            s.put(&format!("u{i}"), "ether_n4", "host", &mk(i)).unwrap();
        }
        assert_eq!(s.len(), 20);
        // 128 B payload + ~38 B framing → 1 record per 256 B page →
        // 20 pages, 19 sealed.
        assert!(s.stats().page_outs >= 8, "{:?}", s.stats());
        for i in 0..20 {
            let r = s.get(&format!("u{i}")).unwrap();
            assert_eq!(r.params, mk(i));
            assert_eq!(r.method, "ether_n4");
            assert_eq!(r.cfg, "host");
        }
        // Far more sealed pages than the 2-page cache → some disk reads.
        assert!(s.stats().page_ins > 0, "{:?}", s.stats());
        assert_eq!(s.total_params(), 20 * 32);
    }

    #[test]
    fn flush_then_cold_read_pages_in() {
        let s = small_store("cold");
        s.put("a", "m", "c", &[1.0, 2.0, 3.0]).unwrap();
        s.flush().unwrap();
        s.drop_caches();
        let before = s.stats().page_ins;
        assert_eq!(s.get("a").unwrap().params, vec![1.0, 2.0, 3.0]);
        assert_eq!(s.stats().page_ins, before + 1);
    }

    #[test]
    fn unknown_id_is_err() {
        let s = small_store("unknown");
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn oversized_record_is_err() {
        let s = small_store("oversize");
        let big = vec![0.0f32; 1024]; // 4 KiB > 256 B page
        let e = s.put("big", "m", "c", &big).unwrap_err();
        assert!(e.to_string().contains("page"), "{e}");
    }

    #[test]
    fn corruption_is_err_not_panic() {
        let s = small_store("corrupt");
        s.put("a", "m", "c", &[5.0; 16]).unwrap();
        s.flush().unwrap();
        s.drop_caches();
        // Flip a payload byte on disk through an independent handle (the
        // record is header 24 B + "a"+"m"+"c" strings, payload at 27).
        let path = s.path().to_path_buf();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[30] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let e = s.get("a").unwrap_err();
        assert!(e.to_string().contains("checksum"), "{e}");
    }

    #[test]
    fn short_read_is_err_not_panic() {
        let s = small_store("shortread");
        s.put("a", "m", "c", &[5.0; 16]).unwrap();
        s.flush().unwrap();
        s.drop_caches();
        // Truncate the file: the page-in read must fail cleanly.
        let f = std::fs::OpenOptions::new().write(true).open(s.path()).unwrap();
        f.set_len(10).unwrap();
        assert!(s.get("a").is_err());
    }

    #[test]
    fn reput_replaces_and_tracks_dead_bytes() {
        let s = small_store("reput");
        s.put("a", "m", "c", &[1.0]).unwrap();
        assert_eq!(s.stats().dead_bytes, 0);
        s.put("a", "m", "c", &[2.0, 3.0]).unwrap();
        assert_eq!(s.get("a").unwrap().params, vec![2.0, 3.0]);
        assert_eq!(s.len(), 1);
        assert_eq!(s.total_params(), 2);
        // The first copy (24 B header + 3 string bytes + 4 B payload) is
        // now dead; under the 0.5 default ratio it is not yet compacted.
        assert_eq!(s.stats().dead_bytes, 31, "{:?}", s.stats());
        assert_eq!(s.stats().live_bytes, 35, "{:?}", s.stats());
    }

    #[test]
    fn auto_compaction_bounds_file_growth() {
        let s = small_store("autocompact");
        // Hammer one id: without compaction the file would grow a page
        // per ~2 re-puts forever. The 0.5 default ratio keeps dead bytes
        // under half the record bytes at all times.
        for i in 0..200 {
            s.put("hot", "m", "c", &[i as f32; 16]).unwrap();
            let st = s.stats();
            assert!(
                st.dead_bytes <= st.live_bytes.max(256),
                "round {i}: dead bytes ran away: {st:?}"
            );
        }
        let st = s.stats();
        assert!(st.compactions > 0, "{st:?}");
        assert_eq!(st.records, 1);
        assert_eq!(s.get("hot").unwrap().params, vec![199.0; 16]);
        // On-disk footprint stays O(live): one 256 B page once compacted
        // (plus at most one page of fresh appends since the last pass).
        let disk = std::fs::metadata(s.path()).unwrap().len();
        assert!(disk <= 2 * 256, "file grew to {disk} B: {st:?}");
    }

    #[test]
    fn explicit_compact_reclaims_and_preserves_records() {
        let s = PagedStore::create(
            StoreCfg::new(tmp("explicit_compact"))
                .page_bytes(256)
                .cache_pages(2)
                .compact_ratio(0.0), // auto off: dead bytes pile up
        )
        .unwrap();
        for i in 0..8 {
            s.put(&format!("u{i}"), "m", "c", &[i as f32; 16]).unwrap();
        }
        for i in 0..8 {
            s.put(&format!("u{i}"), "m", "c", &[(i + 100) as f32; 16]).unwrap();
        }
        assert!(s.stats().dead_bytes > 0);
        s.compact().unwrap();
        let st = s.stats();
        assert_eq!(st.dead_bytes, 0, "{st:?}");
        assert_eq!(st.compactions, 1);
        assert_eq!(st.records, 8);
        for i in 0..8 {
            assert_eq!(s.get(&format!("u{i}")).unwrap().params, vec![(i + 100) as f32; 16]);
        }
        // Live: 8 records × 92 B framed, packed 2 per 256 B page → 4 pages.
        let disk = std::fs::metadata(s.path()).unwrap().len();
        assert!(disk <= 4 * 256, "file is {disk} B after compaction: {st:?}");
    }

    #[test]
    fn open_recovers_all_records_including_reputs() {
        let cfg = || StoreCfg::new(tmp("recover")).page_bytes(256).cache_pages(2);
        let s = PagedStore::create(cfg()).unwrap();
        for i in 0..10 {
            s.put(&format!("r{i}"), "ether_n4", "host", &[i as f32; 16]).unwrap();
        }
        s.put("r3", "ether_n4", "host", &[99.0; 16]).unwrap(); // later copy wins
        s.flush().unwrap();
        drop(s);

        let s = PagedStore::open(cfg()).unwrap();
        assert_eq!(s.len(), 10);
        for i in 0..10 {
            let want = if i == 3 { 99.0 } else { i as f32 };
            assert_eq!(s.get(&format!("r{i}")).unwrap().params, vec![want; 16]);
        }
        let st = s.stats();
        assert!(st.dead_bytes > 0, "overridden r3 copy must count as dead: {st:?}");
        // New puts land on a fresh page past the recovered ones.
        s.put("new", "m", "c", &[7.0]).unwrap();
        assert_eq!(s.get("new").unwrap().params, vec![7.0]);
    }

    #[test]
    fn open_recovers_fully_written_records_and_drops_torn_tail() {
        // 40 f32 = 160 B payload + 28 B framing = 188 B → exactly one
        // record per 256 B page, so offsets are deterministic.
        let cfg = || StoreCfg::new(tmp("torn")).page_bytes(256).cache_pages(2);
        let s = PagedStore::create(cfg()).unwrap();
        for i in 0..10 {
            s.put(&format!("r{i}"), "m", "c", &[i as f32; 40]).unwrap();
        }
        s.flush().unwrap();
        drop(s);

        // Simulate a crash mid-append: cut into the last record's
        // payload (10 pages × 256 B, r9 occupies bytes 2304..2492).
        let path = tmp("torn");
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 10 * 256);
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(9 * 256 + 100).unwrap();
        drop(f);

        let s = PagedStore::open(cfg()).unwrap();
        assert_eq!(s.len(), 9, "every fully-written record recovers");
        for i in 0..9 {
            assert_eq!(s.get(&format!("r{i}")).unwrap().params, vec![i as f32; 40]);
        }
        // The torn record is gone, and says so cleanly.
        let e = s.get("r9").unwrap_err();
        assert!(e.to_string().contains("unknown adapter"), "{e}");
        // The tail was padded back to page alignment; appends continue.
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 10 * 256);
        s.put("r9", "m", "c", &[9.0; 40]).unwrap();
        assert_eq!(s.get("r9").unwrap().params, vec![9.0; 40]);
    }

    #[test]
    fn open_stops_at_corrupt_record_but_keeps_other_pages() {
        let cfg = || StoreCfg::new(tmp("bitrot")).page_bytes(256).cache_pages(2);
        let s = PagedStore::create(cfg()).unwrap();
        for i in 0..6 {
            s.put(&format!("r{i}"), "m", "c", &[i as f32; 40]).unwrap(); // 1/page
        }
        s.flush().unwrap();
        drop(s);

        // Bit-rot a payload byte of r2 (page 2 starts at 512; payload
        // starts 28 B in).
        let path = tmp("bitrot");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[2 * 256 + 40] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let s = PagedStore::open(cfg()).unwrap();
        assert_eq!(s.len(), 5, "only the corrupt record is dropped");
        assert!(s.get("r2").is_err());
        for i in [0usize, 1, 3, 4, 5] {
            assert_eq!(s.get(&format!("r{i}")).unwrap().params, vec![i as f32; 40]);
        }
    }

    #[test]
    fn resident_bytes_bounded_by_cache() {
        let s = small_store("bounded");
        for i in 0..200 {
            s.put(&format!("u{i}"), "m", "c", &[i as f32; 16]).unwrap();
        }
        for i in 0..200 {
            s.get(&format!("u{i}")).unwrap();
        }
        // open page + 2 cached pages at 256 B each.
        assert!(s.stats().resident_bytes <= 3 * 256, "{:?}", s.stats());
    }
}
