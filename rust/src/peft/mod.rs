//! Host-side implementation of the PEFT transform family.
//!
//! The authoritative training-time transforms live in the Layer-1 Pallas
//! kernels; this module re-implements them on host tensors for everything
//! the coordinator and the analysis drivers need *without* a PJRT round
//! trip:
//!
//! * merging adapters into base weights on the serving path,
//! * the perturbation / distance studies (paper Figs. 3, 4),
//! * hyperspherical-energy analysis (paper Fig. 7),
//! * property tests of the paper's mathematical claims (Eq. 2, §3.2/§3.3).
//!
//! Parity with the kernels is enforced by `rust/tests/transform_props.rs`
//! (same math) and transitively by the Python kernel-vs-oracle tests.

pub mod apply;
pub mod flat;
pub mod metrics;
pub mod transforms;

use anyhow::{bail, Result};

/// Method family member (mirrors `python/compile/peft.py::MethodSpec`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MethodSpec {
    pub kind: MethodKind,
    pub n_blocks: usize,
    pub rank: usize,
    pub sides: u8,
    pub magnitude_refit: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MethodKind {
    Ether,
    EtherPlus,
    Oft,
    Naive,
    Lora,
    Vera,
    Full,
    None,
}

impl MethodKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MethodKind::Ether => "ether",
            MethodKind::EtherPlus => "etherplus",
            MethodKind::Oft => "oft",
            MethodKind::Naive => "naive",
            MethodKind::Lora => "lora",
            MethodKind::Vera => "vera",
            MethodKind::Full => "full",
            MethodKind::None => "none",
        }
    }

    /// Multiplicative methods transform W by matrix multiplication; the
    /// paper's §5.3 control study hinges on this split.
    pub fn is_multiplicative(&self) -> bool {
        matches!(
            self,
            MethodKind::Ether | MethodKind::EtherPlus | MethodKind::Oft | MethodKind::Naive
        )
    }
}

impl MethodSpec {
    pub fn parse(name: &str) -> Result<MethodSpec> {
        let mut spec = MethodSpec {
            kind: MethodKind::None,
            n_blocks: 4,
            rank: 8,
            sides: 2,
            magnitude_refit: false,
        };
        if name == "full" {
            spec.kind = MethodKind::Full;
            return Ok(spec);
        }
        if name == "none" {
            return Ok(spec);
        }
        let (base, tail) = match name.split_once('_') {
            Some(x) => x,
            None => bail!("unknown method {name:?}"),
        };
        let mut tail = tail.to_string();
        if let Some(t) = tail.strip_suffix("_1s") {
            spec.sides = 1;
            tail = t.to_string();
        }
        if let Some(t) = tail.strip_suffix("_mrf") {
            spec.magnitude_refit = true;
            tail = t.to_string();
        }
        let num: usize = tail
            .get(1..)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad method suffix in {name:?}"))?;
        spec.kind = match base {
            "ether" => MethodKind::Ether,
            "etherplus" => MethodKind::EtherPlus,
            "oft" => MethodKind::Oft,
            "naive" => MethodKind::Naive,
            "lora" => MethodKind::Lora,
            "vera" => MethodKind::Vera,
            _ => bail!("unknown method {name:?}"),
        };
        match spec.kind {
            MethodKind::Lora | MethodKind::Vera => spec.rank = num,
            _ => spec.n_blocks = num,
        }
        Ok(spec)
    }

    pub fn name(&self) -> String {
        match self.kind {
            MethodKind::Ether => format!("ether_n{}", self.n_blocks),
            MethodKind::EtherPlus => format!(
                "etherplus_n{}{}",
                self.n_blocks,
                if self.sides == 1 { "_1s" } else { "" }
            ),
            MethodKind::Oft => format!(
                "oft_n{}{}",
                self.n_blocks,
                if self.magnitude_refit { "_mrf" } else { "" }
            ),
            MethodKind::Naive => format!("naive_n{}", self.n_blocks),
            MethodKind::Lora => format!("lora_r{}", self.rank),
            MethodKind::Vera => format!("vera_r{}", self.rank),
            MethodKind::Full => "full".into(),
            MethodKind::None => "none".into(),
        }
    }
}

/// The six adapted matrices of each transformer layer with their (rows,
/// cols) resolved against model dims (mirrors `peft.py::ADAPTED_MATRICES`).
pub fn adapted_matrices(d_model: usize, d_ff: usize) -> Vec<(&'static str, usize, usize)> {
    vec![
        ("wq", d_model, d_model),
        ("wk", d_model, d_model),
        ("wv", d_model, d_model),
        ("wo", d_model, d_model),
        ("w1", d_model, d_ff),
        ("w2", d_ff, d_model),
    ]
}

/// Exact trainable-parameter count (paper §4 "Parameter Efficiency").
pub fn count_params(d_model: usize, d_ff: usize, n_layers: usize, spec: &MethodSpec) -> usize {
    let per_layer: usize = adapted_matrices(d_model, d_ff)
        .iter()
        .map(|&(_, d, f)| match spec.kind {
            MethodKind::Ether => d,
            MethodKind::EtherPlus => {
                if spec.sides == 2 {
                    2 * d + 2 * f
                } else {
                    2 * d
                }
            }
            MethodKind::Oft => d * d / spec.n_blocks + if spec.magnitude_refit { f } else { 0 },
            MethodKind::Naive => d * d / spec.n_blocks,
            MethodKind::Lora => spec.rank * (d + f),
            MethodKind::Vera => spec.rank + f,
            MethodKind::Full => d * f,
            MethodKind::None => 0,
        })
        .sum();
    per_layer * n_layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for name in [
            "ether_n4", "ether_n32", "etherplus_n4", "etherplus_n4_1s", "oft_n256",
            "oft_n4_mrf", "naive_n4", "lora_r8", "vera_r64", "full", "none",
        ] {
            assert_eq!(MethodSpec::parse(name).unwrap().name(), name, "{name}");
        }
        assert!(MethodSpec::parse("bogus_x2").is_err());
    }

    #[test]
    fn param_formulas_match_paper_shape() {
        // tiny config dims (d=64, f=128, L=2) — mirrors python tests.
        let (d, f, l) = (64, 128, 2);
        let ether = MethodSpec::parse("ether_n4").unwrap();
        assert_eq!(count_params(d, f, l, &ether), l * (5 * d + f));
        // ETHER count independent of n (paper §3.4 headline property).
        let e16 = MethodSpec::parse("ether_n16").unwrap();
        assert_eq!(count_params(d, f, l, &ether), count_params(d, f, l, &e16));
        // OFT scales as d²/n.
        let o4 = MethodSpec::parse("oft_n4").unwrap();
        let o16 = MethodSpec::parse("oft_n16").unwrap();
        assert_eq!(count_params(d, f, l, &o4), 4 * count_params(d, f, l, &o16));
        // ETHER < everything else.
        for other in ["etherplus_n4", "oft_n16", "lora_r8", "full"] {
            let spec = MethodSpec::parse(other).unwrap();
            assert!(
                count_params(d, f, l, &ether) < count_params(d, f, l, &spec),
                "{other}"
            );
        }
    }

    #[test]
    fn multiplicative_split() {
        assert!(MethodKind::Ether.is_multiplicative());
        assert!(MethodKind::Oft.is_multiplicative());
        assert!(!MethodKind::Lora.is_multiplicative());
        assert!(!MethodKind::Vera.is_multiplicative());
    }
}
