//! Host-side implementation of the PEFT transform family.
//!
//! The authoritative training-time transforms live in the Layer-1 Pallas
//! kernels; this module re-implements them on host tensors for everything
//! the coordinator and the analysis drivers need *without* a PJRT round
//! trip:
//!
//! * merging adapters into base weights on the serving path,
//! * the perturbation / distance studies (paper Figs. 3, 4),
//! * hyperspherical-energy analysis (paper Fig. 7),
//! * property tests of the paper's mathematical claims (Eq. 2, §3.2/§3.3).
//!
//! Parity with the kernels is enforced by `rust/tests/transform_props.rs`
//! (same math) and transitively by the Python kernel-vs-oracle tests.
//!
//! Since the `TransformOp` redesign, per-method behaviour lives behind
//! the [`op::TransformOp`] trait, dispatched through [`registry::op_for`]
//! — name parsing, parameter counting, layout construction, merge
//! kernels, unmerge (the involution/inversion path the serving swap mode
//! exploits) and the Fig. 4 distance metric are all derived from it.

pub mod apply;
pub mod blocktune;
pub mod flat;
pub mod metrics;
pub mod op;
pub mod precision;
pub mod registry;
pub mod store;
pub mod transforms;

use anyhow::{bail, ensure, Result};

use op::Arity;

/// Method family member (mirrors `python/compile/peft.py::MethodSpec`;
/// `delora` and `hyperadapt` are host-only extensions with no Layer-2
/// counterpart yet).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MethodSpec {
    pub kind: MethodKind,
    pub n_blocks: usize,
    pub rank: usize,
    pub sides: u8,
    pub magnitude_refit: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MethodKind {
    Ether,
    EtherPlus,
    Oft,
    Naive,
    Lora,
    Vera,
    Delora,
    HyperAdapt,
    Full,
    None,
}

impl MethodKind {
    pub fn as_str(&self) -> &'static str {
        registry::op_for(*self).token()
    }

    /// Multiplicative methods transform W by matrix multiplication; the
    /// paper's §5.3 control study hinges on this split.
    pub fn is_multiplicative(&self) -> bool {
        registry::op_for(*self).is_multiplicative()
    }
}

impl MethodSpec {
    pub fn parse(name: &str) -> Result<MethodSpec> {
        let mut spec = MethodSpec {
            kind: MethodKind::None,
            n_blocks: 4,
            rank: 8,
            sides: 2,
            magnitude_refit: false,
        };
        // Suffix-less members (`full`, `none`).
        if let Some(op) = registry::by_token(name) {
            if op.arity() == Arity::Fixed {
                spec.kind = op.kind();
                return Ok(spec);
            }
        }
        let (base, tail) = match name.split_once('_') {
            Some(x) => x,
            None => bail!("unknown method {name:?}"),
        };
        let mut tail = tail.to_string();
        if let Some(t) = tail.strip_suffix("_1s") {
            spec.sides = 1;
            tail = t.to_string();
        }
        if let Some(t) = tail.strip_suffix("_mrf") {
            spec.magnitude_refit = true;
            tail = t.to_string();
        }
        let num: usize = tail
            .get(1..)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad method suffix in {name:?}"))?;
        let op = registry::by_token(base).ok_or_else(|| anyhow::anyhow!("unknown method {name:?}"))?;
        spec.kind = op.kind();
        match op.arity() {
            Arity::Blocks => {
                ensure!(num > 0, "n_blocks must be > 0 in {name:?}");
                spec.n_blocks = num;
            }
            Arity::Rank => {
                ensure!(num > 0, "rank must be > 0 in {name:?}");
                spec.rank = num;
            }
            Arity::Fixed => bail!("method {base:?} takes no numeric suffix ({name:?})"),
        }
        // Only canonical names parse: the suffix letter must match the
        // op's arity ("ether_r4" ≠ "ether_n4") and flag suffixes are
        // rejected on methods whose canonical name never renders them
        // ("lora_r8_mrf" would silently drop the flag). One registry-
        // derived check instead of per-method letter tables.
        let canonical = op.spec_name(&spec);
        ensure!(
            canonical == name,
            "non-canonical method name {name:?} (did you mean {canonical:?}?)"
        );
        Ok(spec)
    }

    pub fn name(&self) -> String {
        registry::op_for(self.kind).spec_name(self)
    }
}

/// The six adapted matrices of each transformer layer with their (rows,
/// cols) resolved against model dims (mirrors `peft.py::ADAPTED_MATRICES`).
pub fn adapted_matrices(d_model: usize, d_ff: usize) -> Vec<(&'static str, usize, usize)> {
    vec![
        ("wq", d_model, d_model),
        ("wk", d_model, d_model),
        ("wv", d_model, d_model),
        ("wo", d_model, d_model),
        ("w1", d_model, d_ff),
        ("w2", d_ff, d_model),
    ]
}

/// Exact trainable-parameter count (paper §4 "Parameter Efficiency"),
/// derived from each op's [`op::TransformOp::param_schema`] — the same
/// source of truth `apply::peft_layout_for` builds flat layouts from.
pub fn count_params(d_model: usize, d_ff: usize, n_layers: usize, spec: &MethodSpec) -> usize {
    let op = registry::op_for(spec.kind);
    let per_layer: usize = adapted_matrices(d_model, d_ff)
        .iter()
        .map(|&(_, d, f)| {
            op.param_schema(spec, d, f)
                .iter()
                .map(|(_, shape)| shape.iter().product::<usize>())
                .sum::<usize>()
        })
        .sum();
    per_layer * n_layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for name in [
            "ether_n4", "ether_n32", "etherplus_n4", "etherplus_n4_1s", "oft_n256",
            "oft_n4_mrf", "naive_n4", "lora_r8", "vera_r64", "delora_r8", "hyperadapt",
            "full", "none",
        ] {
            assert_eq!(MethodSpec::parse(name).unwrap().name(), name, "{name}");
        }
        assert!(MethodSpec::parse("bogus_x2").is_err());
    }

    #[test]
    fn parse_rejects_degenerate_arity() {
        // n_blocks = 0 used to parse and divide by zero at layout time.
        for name in ["ether_n0", "etherplus_n0", "oft_n0", "naive_n0", "lora_r0", "vera_r0",
                     "delora_r0"] {
            assert!(MethodSpec::parse(name).is_err(), "{name} must be rejected");
        }
        // Suffix-less methods reject stray suffixes.
        assert!(MethodSpec::parse("full_n4").is_err());
        assert!(MethodSpec::parse("none_r2").is_err());
        assert!(MethodSpec::parse("hyperadapt_n4").is_err());
        // The suffix letter must match the op's arity, and flag suffixes
        // are rejected where the canonical name never renders them.
        assert!(MethodSpec::parse("ether_r4").is_err());
        assert!(MethodSpec::parse("lora_n8").is_err());
        assert!(MethodSpec::parse("lora_r8_mrf").is_err());
        assert!(MethodSpec::parse("ether_n4_1s").is_err());
        assert!(MethodSpec::parse("ether_n04").is_err());
    }

    #[test]
    fn param_formulas_match_paper_shape() {
        // tiny config dims (d=64, f=128, L=2) — mirrors python tests.
        let (d, f, l) = (64, 128, 2);
        let ether = MethodSpec::parse("ether_n4").unwrap();
        assert_eq!(count_params(d, f, l, &ether), l * (5 * d + f));
        // ETHER count independent of n (paper §3.4 headline property).
        let e16 = MethodSpec::parse("ether_n16").unwrap();
        assert_eq!(count_params(d, f, l, &ether), count_params(d, f, l, &e16));
        // OFT scales as d²/n.
        let o4 = MethodSpec::parse("oft_n4").unwrap();
        let o16 = MethodSpec::parse("oft_n16").unwrap();
        assert_eq!(count_params(d, f, l, &o4), 4 * count_params(d, f, l, &o16));
        // ETHER < everything else.
        for other in ["etherplus_n4", "oft_n16", "lora_r8", "delora_r8", "hyperadapt", "full"] {
            let spec = MethodSpec::parse(other).unwrap();
            assert!(
                count_params(d, f, l, &ether) < count_params(d, f, l, &spec),
                "{other}"
            );
        }
        // DeLoRA = LoRA + one strength scalar per adapted matrix.
        let lora = MethodSpec::parse("lora_r8").unwrap();
        let delora = MethodSpec::parse("delora_r8").unwrap();
        assert_eq!(count_params(d, f, l, &delora), count_params(d, f, l, &lora) + 6 * l);
    }

    #[test]
    fn multiplicative_split() {
        assert!(MethodKind::Ether.is_multiplicative());
        assert!(MethodKind::Oft.is_multiplicative());
        assert!(!MethodKind::Lora.is_multiplicative());
        assert!(!MethodKind::Vera.is_multiplicative());
        assert!(!MethodKind::Delora.is_multiplicative());
    }
}
