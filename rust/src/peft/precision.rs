//! Reduced-precision residency for merged weight buffers.
//!
//! Merging always **accumulates in f64** (the kernels in
//! [`transforms`](crate::peft::transforms) never changed); precision here
//! is purely a *storage* decision for the merged copy that sits in the
//! [`MergedCache`](crate::coordinator::registry::MergedCache) LRU. A
//! cached adapter is a full base-sized buffer, so halving its residency
//! (bf16) doubles how many adapters fit in the same cache budget — the
//! lever `ETHER_MERGED_PRECISION` exposes (see
//! [`RuntimeCfg`](crate::util::runtimecfg::RuntimeCfg)).
//!
//! Two modes:
//!
//! * [`MergedPrecision::F32`] (default) — the merge output is stored
//!   bit-exactly; decode is an `Arc` refcount bump. Every pre-existing
//!   bit-identity contract (swap rebase, involution audit, serving tags)
//!   holds unchanged.
//! * [`MergedPrecision::Bf16`] — the f32 merge output is rounded to
//!   bfloat16 (round-to-nearest-even on the truncated mantissa bit),
//!   halving resident bytes. Decode widens by shifting the 16 stored
//!   bits back into the f32 exponent/high-mantissa — exact, so the
//!   only error is the single rounding at encode time:
//!   `|decoded − x| ≤ |x|·2⁻⁸` for normal `x` ([`BF16_REL_BOUND`]),
//!   which `rust/tests/engine_parity.rs` asserts against the f64-path
//!   merge across the whole host-mergeable registry.
//!
//! bf16 keeps f32's full 8-bit exponent (unlike f16), so no merge value
//! can flush to zero on encode, and none can overflow either: the one
//! finite corner case — values in the last half-ulp below `f32::MAX`,
//! whose round-to-nearest carry would spill into the exponent and
//! encode `+inf` — **saturates to the max finite bf16** instead (±inf
//! inputs still pass through exactly). Range is preserved, only
//! mantissa width is traded, and the saturation error stays within
//! [`BF16_REL_BOUND`].

use std::sync::Arc;

/// Storage precision for cached merged weights. Parsed from
/// `ETHER_MERGED_PRECISION` (`"f32"` | `"bf16"`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MergedPrecision {
    /// Bit-exact f32 storage (4 bytes/elem) — the historical behaviour.
    #[default]
    F32,
    /// bfloat16 storage (2 bytes/elem): f32 range, 8-bit mantissa.
    Bf16,
}

/// Relative error bound of one f32 → bf16 round-to-nearest-even step for
/// normal values: half an ulp of the 8-bit (1 implicit + 7 stored)
/// mantissa, i.e. `2⁻⁸`. Subnormals round with *absolute* error below
/// `2⁻¹³³`, far under [`BF16_ABS_SLACK`].
pub const BF16_REL_BOUND: f32 = 1.0 / 256.0;

/// Absolute slack covering subnormal rounding when asserting the bf16
/// round-trip bound (`|decoded − x| ≤ |x|·BF16_REL_BOUND + BF16_ABS_SLACK`).
pub const BF16_ABS_SLACK: f32 = 1e-30;

impl MergedPrecision {
    /// Lenient parse (case-insensitive); unknown strings → `None`, so
    /// garbage env values fall through to the default like every other
    /// `ETHER_*` knob.
    pub fn parse(s: &str) -> Option<MergedPrecision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "full" => Some(MergedPrecision::F32),
            "bf16" | "bfloat16" => Some(MergedPrecision::Bf16),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            MergedPrecision::F32 => "f32",
            MergedPrecision::Bf16 => "bf16",
        }
    }

    pub fn bytes_per_elem(self) -> usize {
        match self {
            MergedPrecision::F32 => 4,
            MergedPrecision::Bf16 => 2,
        }
    }

    /// Resident bytes of an `n`-element merged buffer stored at this
    /// precision — the number [`PagedStore`](crate::peft::store) page
    /// sizing and the fleet resident-bytes accounting see.
    pub fn buf_bytes(self, n: usize) -> usize {
        n * self.bytes_per_elem()
    }
}

/// f32 → bf16 with round-to-nearest-even on the truncated mantissa bit.
/// NaNs are quieted (payload may change, NaN-ness never lost); ±inf and
/// ±0 pass through exactly. Finite values whose rounding carry would
/// overflow the exponent (the last half-ulp up to ±`f32::MAX`) saturate
/// to the max finite bf16 — encode never turns a finite weight into an
/// infinity.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep sign + exponent, force a quiet-NaN mantissa bit so the
        // truncation cannot produce an infinity.
        return ((bits >> 16) as u16) | 0x0040;
    }
    if x.is_infinite() {
        return (bits >> 16) as u16;
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    let b = ((bits + round) >> 16) as u16;
    if b & 0x7FFF == 0x7F80 {
        // The carry spilled into the exponent (finite input in the last
        // half-ulp below ±f32::MAX): saturate to the max finite bf16.
        (b & 0x8000) | 0x7F7F
    } else {
        b
    }
}

/// bf16 → f32 (exact: widen by shifting into the high half).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// A cached merged-weight buffer at its storage precision. Constructed
/// once per merge via [`MergedBuf::encode`]; served to the execution
/// strategies via [`MergedBuf::to_f32`].
#[derive(Clone)]
pub enum MergedBuf {
    F32(Arc<Vec<f32>>),
    Bf16(Arc<Vec<u16>>),
}

impl MergedBuf {
    /// Store `v` at `precision`. f32 mode takes ownership without a copy.
    pub fn encode(v: Vec<f32>, precision: MergedPrecision) -> MergedBuf {
        match precision {
            MergedPrecision::F32 => MergedBuf::F32(Arc::new(v)),
            MergedPrecision::Bf16 => {
                MergedBuf::Bf16(Arc::new(v.iter().map(|&x| f32_to_bf16(x)).collect()))
            }
        }
    }

    /// Widen to f32 for the compute paths. f32 storage is an `Arc`
    /// refcount bump (hits stay lock-then-clone cheap and bit-exact);
    /// bf16 storage decodes into a fresh buffer — the residency saving
    /// is in the *cache*, not in a transient serving buffer.
    pub fn to_f32(&self) -> Arc<Vec<f32>> {
        match self {
            MergedBuf::F32(v) => v.clone(),
            MergedBuf::Bf16(v) => Arc::new(v.iter().map(|&b| bf16_to_f32(b)).collect()),
        }
    }

    pub fn precision(&self) -> MergedPrecision {
        match self {
            MergedBuf::F32(_) => MergedPrecision::F32,
            MergedBuf::Bf16(_) => MergedPrecision::Bf16,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            MergedBuf::F32(v) => v.len(),
            MergedBuf::Bf16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes this buffer holds resident — what
    /// [`MergedCache::resident_bytes`](crate::coordinator::registry::MergedCache::resident_bytes)
    /// sums and `StatsSnapshot`/`FleetSnapshot` report upward.
    pub fn resident_bytes(&self) -> usize {
        self.precision().buf_bytes(self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_is_lenient_and_case_insensitive() {
        assert_eq!(MergedPrecision::parse("f32"), Some(MergedPrecision::F32));
        assert_eq!(MergedPrecision::parse("BF16"), Some(MergedPrecision::Bf16));
        assert_eq!(MergedPrecision::parse("bfloat16"), Some(MergedPrecision::Bf16));
        assert_eq!(MergedPrecision::parse("fp8"), None);
        assert_eq!(MergedPrecision::default(), MergedPrecision::F32);
    }

    #[test]
    fn bf16_round_to_nearest_even_pins() {
        // Exactly representable values pass through.
        for x in [0.0f32, -0.0, 1.0, -2.5, 256.0, f32::INFINITY, f32::NEG_INFINITY] {
            assert_eq!(bf16_to_f32(f32_to_bf16(x)).to_bits(), x.to_bits(), "{x}");
        }
        // 1 + 2⁻⁸ sits exactly between bf16(1.0) and bf16(1 + 2⁻⁷):
        // ties-to-even keeps the even mantissa (1.0).
        let tie = f32::from_bits(0x3F80_8000);
        assert_eq!(bf16_to_f32(f32_to_bf16(tie)), 1.0);
        // One ulp above the tie rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(bf16_to_f32(f32_to_bf16(above)), 1.0 + 1.0 / 128.0);
        // NaN survives (quieted).
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // ±f32::MAX sits in the last half-ulp whose rounding carry would
        // overflow the exponent: encode must saturate to the max finite
        // bf16 (0x7F7F), never round a finite weight to ±inf.
        let max_finite = bf16_to_f32(0x7F7F);
        assert!(max_finite.is_finite());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::MAX)).to_bits(), max_finite.to_bits());
        assert_eq!(bf16_to_f32(f32_to_bf16(-f32::MAX)).to_bits(), (-max_finite).to_bits());
        // Saturation stays within the documented relative bound.
        assert!((max_finite - f32::MAX).abs() <= f32::MAX * BF16_REL_BOUND);
    }

    #[test]
    fn bf16_roundtrip_within_documented_bound() {
        let mut rng = crate::util::rng::Rng::new(77);
        for &scale in &[1e-6f32, 1.0, 1e6] {
            for x in rng.normal_vec(4096, scale) {
                let rt = bf16_to_f32(f32_to_bf16(x));
                let err = (rt - x).abs();
                assert!(
                    err <= x.abs() * BF16_REL_BOUND + BF16_ABS_SLACK,
                    "x={x} rt={rt} err={err}"
                );
            }
        }
    }

    #[test]
    fn buf_residency_and_decode() {
        let v: Vec<f32> = (0..100).map(|i| i as f32 * 0.25).collect();
        let full = MergedBuf::encode(v.clone(), MergedPrecision::F32);
        let half = MergedBuf::encode(v.clone(), MergedPrecision::Bf16);
        assert_eq!(full.resident_bytes(), 400);
        assert_eq!(half.resident_bytes(), 200);
        assert_eq!((full.len(), half.len()), (100, 100));
        // f32 decode is the same allocation; bf16 decode is exact here
        // (quarter-integers up to 25 are bf16-representable).
        let a = full.to_f32();
        let b = full.to_f32();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(half.to_f32().as_ref(), &v);
    }
}
