//! Apply a PEFT adapter to full base weights on the host ("host merge").
//!
//! The serving coordinator uses this path (or the HLO `merge` artifact)
//! on its merge-cache-miss hot path; it also backs (a) the perturbation
//! and distance studies that sweep transform parameters without a
//! runtime, (b) parity tests against the artifact, and (c) the merge
//! micro-benchmarks.
//!
//! The engine is a [`MergePlan`]: all (matrix, layer) work items are
//! enumerated once against the base layout, parameter views are resolved
//! up front through each method's [`crate::peft::op::TransformOp`]
//! schema, and the sweep executes as one `parallel_for_chunks` pass in
//! which each worker writes its items' transformed weights **directly
//! into the output buffer** through the layout offsets — no per-matrix
//! `Mat` clones. Work items run the op's single-threaded
//! `apply_into` slice kernel, which is bit-deterministic, so the
//! parallel sweep is bit-identical to [`MergePlan::execute_serial`]
//! (locked in by `rust/tests/merge_parallel.rs`).
//!
//! On top of the plain merge, the plan exposes the **in-place swap**
//! primitives the serving layer's O(1)-buffer mode is built on:
//!
//! * [`MergePlan::execute_rebase`] — re-merge a new adapter over a
//!   buffer that already holds a merged model, reading adapted regions
//!   from the frozen base and *skipping* the gap copies (the buffer
//!   invariant keeps non-adapted regions at base bits). Bit-identical
//!   to a fresh [`MergePlan::execute`] into a new buffer.
//! * [`MergePlan::execute_unmerge`] — invert the currently merged
//!   adapter in place via the op's `unmerge_into` (ETHER's reflection
//!   is its own inverse, Eq. 1/§3.2; ETHER+/OFT/Naive invert through
//!   Woodbury/transpose/block-inverse structure).
//! * [`MergePlan::execute_swap_involution`] — fused unmerge(old) +
//!   merge(new) per work item, never reading the base inside adapted
//!   regions; optionally audits the recovered weights against the true
//!   base and reports the max involution residual.
//!
//! **Composition stacks** generalize every mode above to an *ordered*
//! adapter stack `[a, b, c]` served as `T_c(T_b(T_a(W)))`:
//! [`MergePlan::execute_stack`] folds the composition into one merged
//! buffer, [`MergePlan::execute_unmerge_stack`] peels it in strict
//! reverse order, [`MergePlan::execute_swap_involution_stack`] swaps
//! whole stacks with a single end-to-end involution audit, and
//! [`MergePlan::execute_activations_stack`] chains each op's affine
//! composition factors (`T(M) = L·M·R + Δ`) around **one** base GEMM
//! for a merge-free composed forward. Composition-*order* logic lives
//! only in this module — ops contribute per-method factors through the
//! `TransformOp::act_*` hooks and never see the stack.
//!
//! Since the host-training PR the plan also carries the **backward**
//! sweep, [`MergePlan::execute_grad_activations`]: the gradient of a
//! loss through the merge-free forward, accumulated per work item into
//! disjoint regions of a flat gradient vector — the engine
//! `train::host::HostTrainer` drives every optimizer step through.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::peft::flat::Layout;
use crate::peft::op::{resolve_params, ActShape, ResolvedParams};
use crate::peft::registry;
use crate::peft::transforms as tf;
use crate::peft::{adapted_matrices, MethodSpec};
use crate::tensor::Mat;
use crate::util::pool::{parallel_for_chunks, parallel_for_chunks_with, SendPtr};
use crate::util::sync::lock_clean;

/// Model dimensions needed to interpret the layer-stacked layouts.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
}

/// Borrowed view of one adapter (spec + flat parameters + their layout)
/// for the swap/unmerge entry points.
#[derive(Clone, Copy)]
pub struct AdapterRef<'a> {
    pub spec: &'a MethodSpec,
    pub peft: &'a [f32],
    pub layout: &'a Layout,
}

/// Extract layer `l` of adapted matrix `name` from the flat base weights.
pub fn weight_matrix(
    base: &[f32],
    base_layout: &Layout,
    name: &str,
    l: usize,
    rows: usize,
    cols: usize,
) -> Result<Mat> {
    let slice = base_layout.view_layer(base, name, l)?;
    anyhow::ensure!(slice.len() == rows * cols);
    Ok(Mat::from_vec(rows, cols, slice.to_vec()))
}

/// Transform one weight matrix with this layer's adapter parameters
/// (blocked parallel kernels; used by the analysis drivers that work on
/// individual matrices rather than whole models). Registry-dispatched:
/// resolves the op's schema views, then runs its blocked engine.
pub fn transform_matrix(
    spec: &MethodSpec,
    peft: &[f32],
    peft_layout: &Layout,
    name: &str,
    l: usize,
    w: &Mat,
) -> Result<Mat> {
    let op = registry::op_for(spec.kind);
    let p = resolve_params(op, spec, peft, peft_layout, name, l, w.rows, w.cols)?;
    op.apply_blocked(spec, &p, w)
}

/// Serial scalar transform of one matrix (reference path only).
fn transform_matrix_serial(
    spec: &MethodSpec,
    peft: &[f32],
    peft_layout: &Layout,
    name: &str,
    l: usize,
    w: &Mat,
) -> Result<Mat> {
    let op = registry::op_for(spec.kind);
    let p = resolve_params(op, spec, peft, peft_layout, name, l, w.rows, w.cols)?;
    op.apply_serial(spec, &p, w)
}

/// One (matrix, layer) unit of merge work, resolved to its flat-vector
/// location in the base layout.
#[derive(Clone, Copy, Debug)]
pub struct MergeItem {
    pub name: &'static str,
    pub layer: usize,
    pub rows: usize,
    pub cols: usize,
    /// Offset of this layer's matrix in the flat base vector.
    pub offset: usize,
}

/// Pre-enumerated merge schedule: every adapted matrix × layer as an
/// independent work item over disjoint output ranges, plus the gap
/// ranges (non-adapted tensors) that are copied through from the base.
pub struct MergePlan {
    pub dims: ModelDims,
    pub items: Vec<MergeItem>,
    /// Ranges of the base vector not covered by any item.
    gaps: Vec<(usize, usize)>,
    base_total: usize,
}

impl MergePlan {
    /// Enumerate all work items once, validating the base layout.
    pub fn new(dims: ModelDims, base_layout: &Layout) -> Result<MergePlan> {
        let mut items = Vec::with_capacity(6 * dims.n_layers);
        for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
            let e = base_layout.entry(name)?;
            anyhow::ensure!(
                e.size == dims.n_layers * d * f,
                "base layout entry {name} has size {} != {} layers × {d}×{f}",
                e.size,
                dims.n_layers
            );
            for l in 0..dims.n_layers {
                items.push(MergeItem {
                    name,
                    layer: l,
                    rows: d,
                    cols: f,
                    offset: e.offset + l * d * f,
                });
            }
        }
        // Complement of the item ranges: copied (not transformed) by the
        // sweep, so `execute` fully writes `out` and callers never need a
        // redundant whole-base pre-copy.
        let mut ranges: Vec<(usize, usize)> =
            items.iter().map(|it| (it.offset, it.offset + it.rows * it.cols)).collect();
        ranges.sort_unstable();
        let mut gaps = vec![];
        let mut pos = 0;
        for (a, b) in ranges {
            if a > pos {
                gaps.push((pos, a));
            }
            pos = pos.max(b);
        }
        if pos < base_layout.total {
            gaps.push((pos, base_layout.total));
        }
        Ok(MergePlan { dims, items, gaps, base_total: base_layout.total })
    }

    /// Largest single work item (scratch sizing for in-place sweeps).
    fn max_item_size(&self) -> usize {
        self.items.iter().map(|it| it.rows * it.cols).max().unwrap_or(0)
    }

    /// Resolve every item's parameter views up front on this thread, so
    /// the parallel sweeps below are infallible.
    fn resolve_all<'a>(
        &self,
        spec: &MethodSpec,
        peft: &'a [f32],
        peft_layout: &Layout,
    ) -> Result<Vec<ResolvedParams<'a>>> {
        let op = registry::op_for(spec.kind);
        self.items
            .iter()
            .map(|it| resolve_params(op, spec, peft, peft_layout, it.name, it.layer, it.rows, it.cols))
            .collect()
    }

    /// Execute the plan as one parallel sweep. `out` is fully written:
    /// adapted regions receive the transformed weights and every other
    /// range is copied through from `base`, so callers can hand in any
    /// correctly-sized buffer (e.g. a freshly zero-allocated one) —
    /// no whole-base pre-copy needed.
    pub fn execute(
        &self,
        spec: &MethodSpec,
        base: &[f32],
        peft: &[f32],
        peft_layout: &Layout,
        out: &mut [f32],
    ) -> Result<()> {
        self.run(spec, base, peft, peft_layout, out, None, true)
    }

    /// Serial driver over the same kernels and item order — the
    /// determinism oracle: [`MergePlan::execute`] must produce identical
    /// bits.
    pub fn execute_serial(
        &self,
        spec: &MethodSpec,
        base: &[f32],
        peft: &[f32],
        peft_layout: &Layout,
        out: &mut [f32],
    ) -> Result<()> {
        self.run(spec, base, peft, peft_layout, out, Some(1), true)
    }

    /// In-place adapter swap, rebase flavour: re-merge `new` over a
    /// buffer that already holds a merged model. Adapted regions are
    /// recomputed from the frozen `base`; gap copies are skipped — the
    /// swap-slot invariant is that non-adapted regions still hold base
    /// bits from the initial full merge. The result is **bit-identical**
    /// to a fresh [`MergePlan::execute`] into a new buffer, without the
    /// buffer allocation or the gap-range memcpy.
    ///
    /// `threads: None` uses the ambient pool; `Some(1)` pins serial.
    pub fn execute_rebase(
        &self,
        new: AdapterRef,
        base: &[f32],
        buf: &mut [f32],
        threads: Option<usize>,
    ) -> Result<()> {
        self.run(new.spec, base, new.peft, new.layout, buf, threads, false)
    }

    fn run(
        &self,
        spec: &MethodSpec,
        base: &[f32],
        peft: &[f32],
        peft_layout: &Layout,
        out: &mut [f32],
        threads: Option<usize>,
        copy_gaps: bool,
    ) -> Result<()> {
        anyhow::ensure!(
            base.len() == self.base_total,
            "base length {} != layout total {}",
            base.len(),
            self.base_total
        );
        anyhow::ensure!(out.len() == base.len(), "output buffer length mismatch");
        let op = registry::op_for(spec.kind);
        anyhow::ensure!(
            op.host_mergeable(),
            "host merge unsupported for {} (use the merge artifact)",
            op.token()
        );
        if op.is_identity() {
            if copy_gaps {
                out.copy_from_slice(base);
            } else {
                for it in &self.items {
                    let size = it.rows * it.cols;
                    out[it.offset..it.offset + size]
                        .copy_from_slice(&base[it.offset..it.offset + size]);
                }
            }
            return Ok(());
        }
        // Pass the non-adapted tensors through.
        if copy_gaps {
            for &(a, b) in &self.gaps {
                out[a..b].copy_from_slice(&base[a..b]);
            }
        }
        // Resolve every parameter view on this thread; the sweep below is
        // then infallible.
        let params = self.resolve_all(spec, peft, peft_layout)?;
        let items = &self.items;
        let params = &params;
        let ptr = SendPtr::new(out.as_mut_ptr());
        let sweep = |a: usize, b: usize| {
            for idx in a..b {
                let it = &items[idx];
                let size = it.rows * it.cols;
                ptr.claim(it.offset, size);
                // SAFETY: layout entries are non-overlapping, so items
                // cover disjoint [offset, offset + size) output ranges.
                let region =
                    unsafe { std::slice::from_raw_parts_mut(ptr.get().add(it.offset), size) };
                let src = &base[it.offset..it.offset + size];
                op.apply_into(spec, &params[idx], src, it.rows, it.cols, region);
            }
        };
        match threads {
            Some(t) => parallel_for_chunks_with(t, items.len(), 1, sweep),
            None => parallel_for_chunks(items.len(), 1, sweep),
        }
        Ok(())
    }

    /// Widest work item (`max cols`) — the row budget of the shared
    /// probe matrix for [`MergePlan::execute_activations`].
    pub fn max_item_cols(&self) -> usize {
        self.items.iter().map(|it| it.cols).max().unwrap_or(0)
    }

    /// Output length of one activation sweep with `m` probe columns
    /// (Σ rows·m over the work items, in item order).
    pub fn activations_out_len(&self, m: usize) -> usize {
        self.items.iter().map(|it| it.rows * m).sum()
    }

    /// Merge-free adapted forward over every work item: item `i`
    /// computes `y_i = T(W_i)·x_i` through the op's
    /// `apply_activations_into` kernel, where `x_i` is the top `cols_i`
    /// rows of the shared `max_item_cols()×m` row-major probe `x` (the
    /// first `cols_i·m` elements). Outputs land concatenated in item
    /// order in `out` ([`MergePlan::activations_out_len`] long). **No
    /// merged `d×f` buffer is ever allocated** — scratch stays
    /// activation-sized, which is the whole point of the serving layer's
    /// `OnTheFly` execution strategy.
    ///
    /// Blocked-parallel over items (`threads: None` = the ambient pool,
    /// `Some(1)` = the serial oracle ordering); per-item kernels are
    /// single-threaded and bit-deterministic over disjoint output
    /// ranges, so results are **bit-identical for any thread count** —
    /// locked in by `rust/tests/engine_parity.rs`.
    pub fn execute_activations(
        &self,
        adapter: AdapterRef,
        base: &[f32],
        x: &[f32],
        m: usize,
        out: &mut [f32],
        threads: Option<usize>,
    ) -> Result<()> {
        anyhow::ensure!(
            base.len() == self.base_total,
            "base length {} != layout total {}",
            base.len(),
            self.base_total
        );
        anyhow::ensure!(m > 0, "activation probe needs at least one column");
        let max_cols = self.max_item_cols();
        anyhow::ensure!(
            x.len() == max_cols * m,
            "probe length {} != {} ({max_cols} rows × {m} columns)",
            x.len(),
            max_cols * m
        );
        anyhow::ensure!(
            out.len() == self.activations_out_len(m),
            "activation output buffer length mismatch"
        );
        let op = registry::op_for(adapter.spec.kind);
        anyhow::ensure!(
            op.supports_activations(),
            "{} does not support activation application",
            op.token()
        );
        let params = self.resolve_all(adapter.spec, adapter.peft, adapter.layout)?;
        // Per-item output offsets: items have heterogeneous row counts.
        let mut offsets = Vec::with_capacity(self.items.len());
        let mut pos = 0usize;
        for it in &self.items {
            offsets.push(pos);
            pos += it.rows * m;
        }
        let items = &self.items;
        let params = &params;
        let offsets = &offsets;
        let spec = adapter.spec;
        let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let ptr = SendPtr::new(out.as_mut_ptr());
        let sweep = |a: usize, b: usize| {
            for idx in a..b {
                let it = &items[idx];
                let size = it.rows * m;
                ptr.claim(offsets[idx], size);
                // SAFETY: the offsets partition `out` into disjoint
                // [offset, offset + rows·m) ranges in item order.
                let region =
                    unsafe { std::slice::from_raw_parts_mut(ptr.get().add(offsets[idx]), size) };
                let src = &base[it.offset..it.offset + it.rows * it.cols];
                let shape = ActShape { d: it.rows, f: it.cols, m };
                if let Err(e) = op.apply_activations_into(
                    spec,
                    &params[idx],
                    src,
                    &x[..it.cols * m],
                    shape,
                    region,
                ) {
                    let mut slot = lock_clean(&err);
                    if slot.is_none() {
                        *slot = Some(e.context(format!("activations {}[{}]", it.name, it.layer)));
                    }
                }
            }
        };
        match threads {
            Some(t) => parallel_for_chunks_with(t, items.len(), 1, sweep),
            None => parallel_for_chunks(items.len(), 1, sweep),
        }
        match err.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Backward companion of [`MergePlan::execute_activations`]:
    /// accumulate `∂L/∂θ` into the flat `grad` vector (laid out exactly
    /// like the adapter's PEFT vector) given `upstream = ∂L/∂y` for the
    /// concatenated activation outputs. Per item, the op's
    /// [`crate::peft::op::TransformOp::grad_params_into`] kernel runs
    /// single-threaded into **disjoint gradient regions** (distinct
    /// (matrix, layer) slices of non-overlapping layout entries), with
    /// the sweep blocked-parallel over items — results are
    /// **bit-identical for any thread count** (`None` = ambient pool,
    /// `Some(1)` = the serial oracle), which `rust/tests/grad_props.rs`
    /// locks in alongside central-finite-difference correctness.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_grad_activations(
        &self,
        adapter: AdapterRef,
        base: &[f32],
        x: &[f32],
        m: usize,
        upstream: &[f32],
        grad: &mut [f32],
        threads: Option<usize>,
    ) -> Result<()> {
        anyhow::ensure!(
            base.len() == self.base_total,
            "base length {} != layout total {}",
            base.len(),
            self.base_total
        );
        anyhow::ensure!(m > 0, "gradient sweep needs at least one activation column");
        let max_cols = self.max_item_cols();
        anyhow::ensure!(
            x.len() == max_cols * m,
            "probe length {} != {} ({max_cols} rows × {m} columns)",
            x.len(),
            max_cols * m
        );
        anyhow::ensure!(
            upstream.len() == self.activations_out_len(m),
            "upstream buffer length mismatch"
        );
        anyhow::ensure!(
            grad.len() == adapter.layout.total,
            "gradient vector length {} != layout total {}",
            grad.len(),
            adapter.layout.total
        );
        let op = registry::op_for(adapter.spec.kind);
        anyhow::ensure!(
            op.supports_grad(),
            "{} does not support parameter gradients",
            op.token()
        );
        let params = self.resolve_all(adapter.spec, adapter.peft, adapter.layout)?;
        // Per-item gradient-field locations, resolved (fallibly) up
        // front — through the same `grad_field_locs` the op-level
        // `resolve_grad` uses — so the sweep below is infallible.
        let mut locs: Vec<Vec<(&'static str, usize, usize)>> = Vec::with_capacity(self.items.len());
        for it in &self.items {
            locs.push(crate::peft::op::grad_field_locs(
                op,
                adapter.spec,
                adapter.layout,
                it.name,
                it.layer,
                it.rows,
                it.cols,
            )?);
        }
        // Upstream offsets (same partition as the activation outputs).
        let mut offsets = Vec::with_capacity(self.items.len());
        let mut pos = 0usize;
        for it in &self.items {
            offsets.push(pos);
            pos += it.rows * m;
        }
        let items = &self.items;
        let (params, locs, offsets) = (&params, &locs, &offsets);
        let spec = adapter.spec;
        let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let gptr = SendPtr::new(grad.as_mut_ptr());
        let sweep = |a: usize, b: usize| {
            for idx in a..b {
                let it = &items[idx];
                let fields: Vec<(&'static str, &mut [f32])> = locs[idx]
                    .iter()
                    .map(|&(field, off, len)| {
                        gptr.claim(off, len);
                        // SAFETY: field locations are disjoint across
                        // items — distinct (matrix, layer) slices of
                        // non-overlapping layout entries — so concurrent
                        // items never alias (the claim above asserts it).
                        (field, unsafe {
                            std::slice::from_raw_parts_mut(gptr.get().add(off), len)
                        })
                    })
                    .collect();
                let mut gp = crate::peft::op::GradParams::from_fields(fields);
                let src = &base[it.offset..it.offset + it.rows * it.cols];
                let g = &upstream[offsets[idx]..offsets[idx] + it.rows * m];
                let shape = ActShape { d: it.rows, f: it.cols, m };
                if let Err(e) = op.grad_params_into(
                    spec,
                    &params[idx],
                    src,
                    &x[..it.cols * m],
                    g,
                    shape,
                    Some(1),
                    &mut gp,
                ) {
                    let mut slot = lock_clean(&err);
                    if slot.is_none() {
                        *slot = Some(e.context(format!("grad {}[{}]", it.name, it.layer)));
                    }
                }
            }
        };
        match threads {
            Some(t) => parallel_for_chunks_with(t, items.len(), 1, sweep),
            None => parallel_for_chunks(items.len(), 1, sweep),
        }
        match err.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Invert `adapter`'s transform **in place** over a merged buffer,
    /// recovering the pre-merge weights in every adapted region (gaps
    /// were plain copies and are left untouched). Requires the op to
    /// support unmerge; errors on numerically non-invertible parameters
    /// (in which case the buffer must be considered poisoned).
    ///
    /// `threads: None` uses the ambient pool; `Some(1)` pins serial —
    /// both produce identical bits (per-item kernels are
    /// single-threaded and item order never affects disjoint regions).
    pub fn execute_unmerge(
        &self,
        adapter: AdapterRef,
        buf: &mut [f32],
        threads: Option<usize>,
    ) -> Result<()> {
        anyhow::ensure!(buf.len() == self.base_total, "buffer length mismatch");
        let op = registry::op_for(adapter.spec.kind);
        anyhow::ensure!(
            op.supports_unmerge(),
            "{} does not support in-place unmerge",
            op.token()
        );
        let params = self.resolve_all(adapter.spec, adapter.peft, adapter.layout)?;
        let max_size = self.max_item_size();
        let items = &self.items;
        let params = &params;
        let spec = adapter.spec;
        let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let ptr = SendPtr::new(buf.as_mut_ptr());
        let sweep = |a: usize, b: usize| {
            let mut scratch = vec![0.0f32; max_size];
            for idx in a..b {
                let it = &items[idx];
                let size = it.rows * it.cols;
                ptr.claim(it.offset, size);
                // SAFETY: items cover disjoint output ranges.
                let region =
                    unsafe { std::slice::from_raw_parts_mut(ptr.get().add(it.offset), size) };
                scratch[..size].copy_from_slice(region);
                if let Err(e) =
                    op.unmerge_into(spec, &params[idx], &scratch[..size], it.rows, it.cols, region)
                {
                    let mut slot = lock_clean(&err);
                    if slot.is_none() {
                        *slot = Some(e.context(format!("unmerge {}[{}]", it.name, it.layer)));
                    }
                }
            }
        };
        match threads {
            Some(t) => parallel_for_chunks_with(t, items.len(), 1, sweep),
            None => parallel_for_chunks(items.len(), 1, sweep),
        }
        match err.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// In-place adapter swap, involution flavour: per work item, invert
    /// `old`'s transform on the merged slice (recovering ≈ base weights
    /// through the paper's involution/inversion structure) and
    /// immediately re-apply `new` — one fused parallel sweep that never
    /// reads the base inside adapted regions.
    ///
    /// When `audit_base` is given, the recovered weights are compared
    /// against it mid-sweep and the max-abs involution residual is
    /// returned (0.0 without an audit). The result agrees with a fresh
    /// merge of `new` to within that residual's amplification (≤ 1e-5
    /// for the family, asserted by tests and the adapter_merge bench);
    /// for exact bit-parity use [`MergePlan::execute_rebase`].
    ///
    /// On error the buffer must be considered poisoned (a fresh merge
    /// restores it).
    pub fn execute_swap_involution(
        &self,
        old: AdapterRef,
        new: AdapterRef,
        audit_base: Option<&[f32]>,
        buf: &mut [f32],
        threads: Option<usize>,
    ) -> Result<f32> {
        // Length-1 stacks run the identical per-item operation sequence,
        // so the singleton swap is the stack swap on one-element stacks.
        self.execute_swap_involution_stack(&[old], &[new], audit_base, buf, threads)
    }

    /// Stack-general involution swap: per work item, unmerge the `old`
    /// composition **in strict reverse composition order** (the last
    /// adapter applied is the first peeled — inverting
    /// `T_k∘…∘T_1` as `T_1⁻¹∘…∘T_k⁻¹`), audit the fully-recovered
    /// weights against `audit_base` (the residual covers the *whole*
    /// stack, not any intermediate), then apply the `new` composition in
    /// forward order. One fused parallel sweep that never reads the base
    /// inside adapted regions; singleton swaps are the one-element
    /// special case ([`MergePlan::execute_swap_involution`] delegates
    /// here).
    pub fn execute_swap_involution_stack(
        &self,
        old: &[AdapterRef],
        new: &[AdapterRef],
        audit_base: Option<&[f32]>,
        buf: &mut [f32],
        threads: Option<usize>,
    ) -> Result<f32> {
        anyhow::ensure!(buf.len() == self.base_total, "buffer length mismatch");
        anyhow::ensure!(!old.is_empty() && !new.is_empty(), "swap stacks must be non-empty");
        for a in old {
            let op = registry::op_for(a.spec.kind);
            anyhow::ensure!(
                op.supports_unmerge(),
                "{} does not support in-place unmerge",
                op.token()
            );
        }
        for a in new {
            let op = registry::op_for(a.spec.kind);
            anyhow::ensure!(
                op.host_mergeable(),
                "host merge unsupported for {} (use the merge artifact)",
                op.token()
            );
        }
        if let Some(base) = audit_base {
            anyhow::ensure!(base.len() == buf.len(), "audit base length mismatch");
        }
        let old_params: Vec<Vec<ResolvedParams>> = old
            .iter()
            .map(|a| self.resolve_all(a.spec, a.peft, a.layout))
            .collect::<Result<_>>()?;
        let new_params: Vec<Vec<ResolvedParams>> = new
            .iter()
            .map(|a| self.resolve_all(a.spec, a.peft, a.layout))
            .collect::<Result<_>>()?;
        let max_size = self.max_item_size();
        let items = &self.items;
        let (old_params, new_params) = (&old_params, &new_params);
        let residual_bits = AtomicU32::new(0);
        let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let ptr = SendPtr::new(buf.as_mut_ptr());
        let sweep = |a: usize, b: usize| {
            let mut scratch = vec![0.0f32; max_size];
            'item: for idx in a..b {
                let it = &items[idx];
                let size = it.rows * it.cols;
                ptr.claim(it.offset, size);
                // SAFETY: items cover disjoint output ranges.
                let region =
                    unsafe { std::slice::from_raw_parts_mut(ptr.get().add(it.offset), size) };
                // Peel the old composition, last-applied first.
                for (ai, adapter) in old.iter().enumerate().rev() {
                    let op = registry::op_for(adapter.spec.kind);
                    scratch[..size].copy_from_slice(region);
                    if let Err(e) = op.unmerge_into(
                        adapter.spec,
                        &old_params[ai][idx],
                        &scratch[..size],
                        it.rows,
                        it.cols,
                        region,
                    ) {
                        let mut slot = lock_clean(&err);
                        if slot.is_none() {
                            *slot =
                                Some(e.context(format!("unmerge {}[{}]", it.name, it.layer)));
                        }
                        continue 'item;
                    }
                }
                if let Some(base) = audit_base {
                    let mut local = 0.0f32;
                    for (x, y) in region.iter().zip(&base[it.offset..it.offset + size]) {
                        local = local.max((x - y).abs());
                    }
                    // f32 bit patterns of non-negative floats order like
                    // the floats themselves, so an integer max works.
                    residual_bits.fetch_max(local.to_bits(), Ordering::Relaxed);
                }
                // Apply the new composition in forward order.
                for (ai, adapter) in new.iter().enumerate() {
                    let op = registry::op_for(adapter.spec.kind);
                    scratch[..size].copy_from_slice(region);
                    op.apply_into(
                        adapter.spec,
                        &new_params[ai][idx],
                        &scratch[..size],
                        it.rows,
                        it.cols,
                        region,
                    );
                }
            }
        };
        match threads {
            Some(t) => parallel_for_chunks_with(t, items.len(), 1, sweep),
            None => parallel_for_chunks(items.len(), 1, sweep),
        }
        if let Some(e) = err.into_inner().unwrap() {
            return Err(e);
        }
        Ok(f32::from_bits(residual_bits.load(Ordering::Relaxed)))
    }

    /// In-place forward application of one adapter over a buffer that
    /// already holds merged weights: per work item, transform the
    /// current region contents (not the frozen base) through the op's
    /// `apply_into`. The building block of composed merges — gaps are
    /// untouched (they hold base bits from the initial merge).
    fn apply_over(&self, adapter: AdapterRef, buf: &mut [f32], threads: Option<usize>) -> Result<()> {
        anyhow::ensure!(buf.len() == self.base_total, "buffer length mismatch");
        let op = registry::op_for(adapter.spec.kind);
        anyhow::ensure!(
            op.host_mergeable(),
            "host merge unsupported for {} (use the merge artifact)",
            op.token()
        );
        let params = self.resolve_all(adapter.spec, adapter.peft, adapter.layout)?;
        let max_size = self.max_item_size();
        let items = &self.items;
        let params = &params;
        let spec = adapter.spec;
        let ptr = SendPtr::new(buf.as_mut_ptr());
        let sweep = |a: usize, b: usize| {
            let mut scratch = vec![0.0f32; max_size];
            for idx in a..b {
                let it = &items[idx];
                let size = it.rows * it.cols;
                ptr.claim(it.offset, size);
                // SAFETY: items cover disjoint output ranges.
                let region =
                    unsafe { std::slice::from_raw_parts_mut(ptr.get().add(it.offset), size) };
                scratch[..size].copy_from_slice(region);
                op.apply_into(spec, &params[idx], &scratch[..size], it.rows, it.cols, region);
            }
        };
        match threads {
            Some(t) => parallel_for_chunks_with(t, items.len(), 1, sweep),
            None => parallel_for_chunks(items.len(), 1, sweep),
        }
        Ok(())
    }

    /// Composed merge of an ordered adapter stack:
    /// `out = T_k(…T_2(T_1(base))…)` — the first adapter merges fresh
    /// (gap copies included), every subsequent adapter applies **over**
    /// the intermediate merged weights in place. A length-1 stack runs
    /// exactly [`MergePlan::execute`] (same kernels, same item order),
    /// so singleton behaviour — including bit-identity across thread
    /// counts — is unchanged.
    pub fn execute_stack(
        &self,
        stack: &[AdapterRef],
        base: &[f32],
        out: &mut [f32],
        threads: Option<usize>,
    ) -> Result<()> {
        anyhow::ensure!(!stack.is_empty(), "adapter stack must be non-empty");
        let first = stack[0];
        self.run(first.spec, base, first.peft, first.layout, out, threads, true)?;
        for adapter in &stack[1..] {
            self.apply_over(*adapter, out, threads)?;
        }
        Ok(())
    }

    /// [`MergePlan::execute_stack`] over a buffer whose gap regions
    /// already hold base bits (the swap-slot invariant): the first
    /// adapter re-merges via [`MergePlan::execute_rebase`] semantics
    /// (adapted regions read from the frozen base, gap copies skipped),
    /// the rest apply over the intermediate. Bit-identical to a fresh
    /// [`MergePlan::execute_stack`] into a new buffer.
    pub fn execute_rebase_stack(
        &self,
        stack: &[AdapterRef],
        base: &[f32],
        buf: &mut [f32],
        threads: Option<usize>,
    ) -> Result<()> {
        anyhow::ensure!(!stack.is_empty(), "adapter stack must be non-empty");
        let first = stack[0];
        self.run(first.spec, base, first.peft, first.layout, buf, threads, false)?;
        for adapter in &stack[1..] {
            self.apply_over(*adapter, buf, threads)?;
        }
        Ok(())
    }

    /// Invert a composed adapter stack **in place**, peeling transforms
    /// in strict reverse composition order (`T_1⁻¹∘…∘T_k⁻¹`): the
    /// inverse of [`MergePlan::execute_stack`]. Errors leave the buffer
    /// poisoned (a fresh merge restores it), exactly like the singleton
    /// [`MergePlan::execute_unmerge`] — which is the length-1 case.
    pub fn execute_unmerge_stack(
        &self,
        stack: &[AdapterRef],
        buf: &mut [f32],
        threads: Option<usize>,
    ) -> Result<()> {
        anyhow::ensure!(!stack.is_empty(), "adapter stack must be non-empty");
        for adapter in stack.iter().rev() {
            self.execute_unmerge(*adapter, buf, threads)?;
        }
        Ok(())
    }

    /// Composed merge-free forward: `y = T_k(…T_1(W)…)·x` per work item
    /// with **zero merged buffers**, chaining the ops' affine
    /// composition factors (`T(M) = L·M·R + Δ`, see
    /// [`crate::peft::op::TransformOp::supports_composition`])
    /// right-to-left around **one** base GEMM:
    ///
    /// ```text
    /// v_k = x;  v_{i-1} = R_i·v_i   (inward pass, i = k … 1)
    /// y = W·(R_0·v_0)               (the single base product)
    /// y = L_i·y + Δ_i·v_i           (outward pass, i = 0 … k)
    /// ```
    ///
    /// Scratch stays activation-sized (`O(k·(d+f)·m)` per item). A
    /// length-1 stack delegates to [`MergePlan::execute_activations`] —
    /// the singleton kernels — so existing on-the-fly serving numerics
    /// (and their bit-identity pins) are untouched. This method is the
    /// **only** home of the composition-order recursion: ops contribute
    /// factors, never ordering logic.
    pub fn execute_activations_stack(
        &self,
        stack: &[AdapterRef],
        base: &[f32],
        x: &[f32],
        m: usize,
        out: &mut [f32],
        threads: Option<usize>,
    ) -> Result<()> {
        anyhow::ensure!(!stack.is_empty(), "adapter stack must be non-empty");
        if stack.len() == 1 {
            return self.execute_activations(stack[0], base, x, m, out, threads);
        }
        anyhow::ensure!(
            base.len() == self.base_total,
            "base length {} != layout total {}",
            base.len(),
            self.base_total
        );
        anyhow::ensure!(m > 0, "activation probe needs at least one column");
        let max_cols = self.max_item_cols();
        anyhow::ensure!(
            x.len() == max_cols * m,
            "probe length {} != {} ({max_cols} rows × {m} columns)",
            x.len(),
            max_cols * m
        );
        anyhow::ensure!(
            out.len() == self.activations_out_len(m),
            "activation output buffer length mismatch"
        );
        for a in stack {
            let op = registry::op_for(a.spec.kind);
            anyhow::ensure!(
                op.supports_composition(),
                "{} does not support activation composition",
                op.token()
            );
        }
        let all_params: Vec<Vec<ResolvedParams>> = stack
            .iter()
            .map(|a| self.resolve_all(a.spec, a.peft, a.layout))
            .collect::<Result<_>>()?;
        let mut offsets = Vec::with_capacity(self.items.len());
        let mut pos = 0usize;
        for it in &self.items {
            offsets.push(pos);
            pos += it.rows * m;
        }
        let items = &self.items;
        let (all_params, offsets) = (&all_params, &offsets);
        let k = stack.len();
        let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let ptr = SendPtr::new(out.as_mut_ptr());
        let sweep = |a: usize, b: usize| {
            'item: for idx in a..b {
                let it = &items[idx];
                let (d, f) = (it.rows, it.cols);
                let size = d * m;
                ptr.claim(offsets[idx], size);
                // SAFETY: the offsets partition `out` into disjoint
                // [offset, offset + rows·m) ranges in item order.
                let region =
                    unsafe { std::slice::from_raw_parts_mut(ptr.get().add(offsets[idx]), size) };
                let src = &base[it.offset..it.offset + d * f];
                let shape = ActShape { d, f, m };
                let mut fail = |e: anyhow::Error| {
                    let mut slot = lock_clean(&err);
                    if slot.is_none() {
                        *slot = Some(e.context(format!(
                            "composed activations {}[{}]",
                            it.name, it.layer
                        )));
                    }
                };
                // Inward pass: v_i is the f×m input seen at stack level
                // i; v_{k-1} = x and each level's right factor feeds the
                // one below.
                let mut vins: Vec<Vec<f32>> = vec![Vec::new(); k];
                vins[k - 1] = x[..f * m].to_vec();
                for i in (1..k).rev() {
                    let op = registry::op_for(stack[i].spec.kind);
                    let mut v = vec![0.0f32; f * m];
                    let (head, tail) = vins.split_at_mut(i);
                    if let Err(e) = op.act_right_into(
                        stack[i].spec,
                        &all_params[i][idx],
                        &tail[0],
                        shape,
                        &mut v,
                    ) {
                        fail(e);
                        continue 'item;
                    }
                    head[i - 1] = v;
                }
                // The single base GEMM, on the innermost right factor.
                let op0 = registry::op_for(stack[0].spec.kind);
                let mut vbase = vec![0.0f32; f * m];
                if let Err(e) =
                    op0.act_right_into(stack[0].spec, &all_params[0][idx], &vins[0], shape, &mut vbase)
                {
                    fail(e);
                    continue 'item;
                }
                let mut y = vec![0.0f32; d * m];
                tf::matmul_tiled_into(src, &vbase, d, f, m, &mut y);
                // Outward pass: left factor, then the additive term fed
                // by that level's input.
                let mut ytmp = vec![0.0f32; d * m];
                for (i, adapter) in stack.iter().enumerate() {
                    let op = registry::op_for(adapter.spec.kind);
                    if let Err(e) =
                        op.act_left_into(adapter.spec, &all_params[i][idx], &y, shape, &mut ytmp)
                    {
                        fail(e);
                        continue 'item;
                    }
                    std::mem::swap(&mut y, &mut ytmp);
                    if let Err(e) =
                        op.act_delta_acc(adapter.spec, &all_params[i][idx], &vins[i], shape, &mut y)
                    {
                        fail(e);
                        continue 'item;
                    }
                }
                region.copy_from_slice(&y);
            }
        };
        match threads {
            Some(t) => parallel_for_chunks_with(t, items.len(), 1, sweep),
            None => parallel_for_chunks(items.len(), 1, sweep),
        }
        match err.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Merge an adapter into a copy of the base weights (all layers, all six
/// adapted matrices) — one blocked parallel sweep. Mirrors the HLO
/// `merge` artifact.
pub fn merge_into_base(
    dims: ModelDims,
    spec: &MethodSpec,
    base: &[f32],
    base_layout: &Layout,
    peft: &[f32],
    peft_layout: &Layout,
) -> Result<Vec<f32>> {
    let plan = MergePlan::new(dims, base_layout)?;
    // Zero-alloc (calloc) rather than cloning the base: the sweep writes
    // every byte (items + gaps), so a base pre-copy would be pure wasted
    // memory bandwidth on the cache-miss hot path.
    let mut out = vec![0.0f32; base.len()];
    plan.execute(spec, base, peft, peft_layout, &mut out)?;
    Ok(out)
}

/// The pre-refactor per-matrix scalar merge, kept as the parity oracle
/// for the blocked engine and as the benchmark baseline.
pub fn merge_into_base_reference(
    dims: ModelDims,
    spec: &MethodSpec,
    base: &[f32],
    base_layout: &Layout,
    peft: &[f32],
    peft_layout: &Layout,
) -> Result<Vec<f32>> {
    let op = registry::op_for(spec.kind);
    anyhow::ensure!(
        op.host_mergeable(),
        "host merge unsupported for {} (use the merge artifact)",
        op.token()
    );
    let mut out = base.to_vec();
    if op.is_identity() {
        return Ok(out);
    }
    for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
        for l in 0..dims.n_layers {
            let w = weight_matrix(base, base_layout, name, l, d, f)?;
            let t = transform_matrix_serial(spec, peft, peft_layout, name, l, &w)?;
            base_layout
                .view_layer_mut(&mut out, name, l)?
                .copy_from_slice(&t.data);
        }
    }
    Ok(out)
}

/// Base layout holding exactly the six adapted matrices, layer-stacked
/// (`[n_layers, d, f]` each) — the synthetic-base convention shared by
/// the host benches, the merge tests, and the PJRT-free serving mode.
/// The companion of [`peft_layout_for`]: together they encode the host
/// side of the L2↔L3 shape contract.
pub fn base_layout_for(dims: ModelDims) -> Layout {
    Layout::new(
        adapted_matrices(dims.d_model, dims.d_ff)
            .into_iter()
            .map(|(name, d, f)| (name.to_string(), vec![dims.n_layers, d, f]))
            .collect(),
    )
}

/// Build the flat PEFT layout for (dims, spec) from the op's parameter
/// schema — the same single source of truth as `peft::count_params` and
/// manifest validation, with each field stacked over layers exactly the
/// way `python/compile/peft.py` packs it.
pub fn peft_layout_for(dims: ModelDims, spec: &MethodSpec) -> Layout {
    let op = registry::op_for(spec.kind);
    let mut items: Vec<(String, Vec<usize>)> = vec![];
    for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
        for (field, shape) in op.param_schema(spec, d, f) {
            let mut full = Vec::with_capacity(shape.len() + 1);
            full.push(dims.n_layers);
            full.extend_from_slice(&shape);
            items.push((format!("{name}.{field}"), full));
        }
    }
    Layout::new(items)
}

/// Cross-check `count_params` against a schema-derived layout — the two
/// must agree because they are computed from the same schema. Exposed
/// for the registry property tests.
pub fn schema_total(dims: ModelDims, spec: &MethodSpec) -> usize {
    peft_layout_for(dims, spec).total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_dims() -> ModelDims {
        ModelDims { d_model: 16, d_ff: 32, n_layers: 2 }
    }

    fn fake_base(dims: ModelDims) -> (Vec<f32>, Layout) {
        // Only the six adapted matrices — enough for merge tests.
        let layout = base_layout_for(dims);
        let mut rng = Rng::new(11);
        (rng.normal_vec(layout.total, 0.05), layout)
    }

    #[test]
    fn merge_plan_enumerates_disjoint_cover() {
        let dims = tiny_dims();
        let (_, bl) = fake_base(dims);
        let plan = MergePlan::new(dims, &bl).unwrap();
        assert_eq!(plan.items.len(), 6 * dims.n_layers);
        let mut ranges: Vec<(usize, usize)> = plan
            .items
            .iter()
            .map(|it| (it.offset, it.offset + it.rows * it.cols))
            .collect();
        ranges.sort();
        for pair in ranges.windows(2) {
            assert!(pair[0].1 <= pair[1].0, "overlapping items {pair:?}");
        }
        let covered: usize = ranges.iter().map(|(a, b)| b - a).sum();
        assert_eq!(covered, bl.total, "items must cover the whole base");
    }

    #[test]
    fn merge_neutral_methods_are_identity() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        for name in ["oft_n4", "naive_n4", "lora_r4", "delora_r4"] {
            let spec = MethodSpec::parse(name).unwrap();
            let pl = peft_layout_for(dims, &spec);
            // zero init except lora.a (any value works since b = 0)
            let peft = vec![0.0; pl.total];
            let merged =
                merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
            let diff: f32 = merged
                .iter()
                .zip(&base)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(diff < 1e-5, "{name}: {diff}");
        }
        // etherplus neutral when v == u
        let spec = MethodSpec::parse("etherplus_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(5);
        let mut peft = vec![0.0; pl.total];
        for (mname, _, _) in adapted_matrices(dims.d_model, dims.d_ff) {
            for l in 0..dims.n_layers {
                let u: Vec<f32> = rng.normal_vec(
                    pl.entry(&format!("{mname}.u")).unwrap().size / dims.n_layers,
                    1.0,
                );
                pl.view_layer_mut(&mut peft, &format!("{mname}.u"), l)
                    .unwrap()
                    .copy_from_slice(&u);
                pl.view_layer_mut(&mut peft, &format!("{mname}.v"), l)
                    .unwrap()
                    .copy_from_slice(&u);
                let ru: Vec<f32> = rng.normal_vec(
                    pl.entry(&format!("{mname}.ru")).unwrap().size / dims.n_layers,
                    1.0,
                );
                pl.view_layer_mut(&mut peft, &format!("{mname}.ru"), l)
                    .unwrap()
                    .copy_from_slice(&ru);
                pl.view_layer_mut(&mut peft, &format!("{mname}.rv"), l)
                    .unwrap()
                    .copy_from_slice(&ru);
            }
        }
        let merged = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
        let diff: f32 = merged
            .iter()
            .zip(&base)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-5, "{diff}");
    }

    #[test]
    fn ether_merge_preserves_frobenius_per_matrix() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let spec = MethodSpec::parse("ether_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(6);
        let peft = rng.normal_vec(pl.total, 1.0);
        let merged = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
        for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
            for l in 0..dims.n_layers {
                let w0 = weight_matrix(&base, &bl, name, l, d, f).unwrap();
                let w1 = weight_matrix(&merged, &bl, name, l, d, f).unwrap();
                assert!((w0.fro() - w1.fro()).abs() < 1e-3, "{name}[{l}]");
                assert!(w0.max_abs_diff(&w1) > 1e-4, "{name}[{l}] unchanged");
            }
        }
    }

    #[test]
    fn vera_host_merge_rejected() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let spec = MethodSpec::parse("vera_r4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let peft = vec![0.0; pl.total];
        assert!(merge_into_base(dims, &spec, &base, &bl, &peft, &pl).is_err());
        assert!(merge_into_base_reference(dims, &spec, &base, &bl, &peft, &pl).is_err());
    }

    #[test]
    fn blocked_merge_matches_reference_oracle() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let mut rng = Rng::new(12);
        for name in ["ether_n4", "etherplus_n4", "etherplus_n2_1s", "oft_n4_mrf", "naive_n2", "lora_r4"] {
            let spec = MethodSpec::parse(name).unwrap();
            let pl = peft_layout_for(dims, &spec);
            let peft = rng.normal_vec(pl.total, 0.3);
            let fast = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
            let slow = merge_into_base_reference(dims, &spec, &base, &bl, &peft, &pl).unwrap();
            let diff: f32 = fast
                .iter()
                .zip(&slow)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(diff <= 1e-5, "{name}: blocked vs reference diff {diff}");
        }
    }

    #[test]
    fn rebase_swap_is_bit_identical_to_fresh_merge() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let plan = MergePlan::new(dims, &bl).unwrap();
        let spec = MethodSpec::parse("ether_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(31);
        let peft_a = rng.normal_vec(pl.total, 0.4);
        let peft_b = rng.normal_vec(pl.total, 0.4);
        let fresh_b = merge_into_base(dims, &spec, &base, &bl, &peft_b, &pl).unwrap();
        let mut buf = merge_into_base(dims, &spec, &base, &bl, &peft_a, &pl).unwrap();
        plan.execute_rebase(
            AdapterRef { spec: &spec, peft: &peft_b, layout: &pl },
            &base,
            &mut buf,
            None,
        )
        .unwrap();
        assert!(
            buf.iter().zip(&fresh_b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "rebase swap must be bit-identical to a fresh merge"
        );
    }

    #[test]
    fn unmerge_recovers_base_within_tolerance() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let plan = MergePlan::new(dims, &bl).unwrap();
        let spec = MethodSpec::parse("ether_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(32);
        let peft = rng.normal_vec(pl.total, 0.4);
        let mut buf = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
        plan.execute_unmerge(AdapterRef { spec: &spec, peft: &peft, layout: &pl }, &mut buf, None)
            .unwrap();
        let err: f32 =
            buf.iter().zip(&base).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
        assert!(err <= 1e-5, "involution residual {err}");
    }

    #[test]
    fn unmerge_rejects_non_invertible_methods() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let plan = MergePlan::new(dims, &bl).unwrap();
        let spec = MethodSpec::parse("full").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(33);
        let peft = rng.normal_vec(pl.total, 0.1);
        let mut buf = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
        let err = plan
            .execute_unmerge(AdapterRef { spec: &spec, peft: &peft, layout: &pl }, &mut buf, None)
            .unwrap_err();
        assert!(err.to_string().contains("unmerge"), "{err}");
    }
}
