//! Apply a PEFT adapter to full base weights on the host ("host merge").
//!
//! The serving coordinator uses the HLO `merge` artifact on its hot path;
//! this host implementation exists for (a) the perturbation and distance
//! studies that sweep transform parameters without a runtime, (b) parity
//! tests against the artifact, and (c) the merge micro-benchmarks.

use anyhow::{bail, Result};

use crate::peft::flat::Layout;
use crate::peft::transforms as tf;
use crate::peft::{adapted_matrices, MethodKind, MethodSpec};
use crate::tensor::Mat;

/// Model dimensions needed to interpret the layer-stacked layouts.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
}

/// Extract layer `l` of adapted matrix `name` from the flat base weights.
pub fn weight_matrix(
    base: &[f32],
    base_layout: &Layout,
    name: &str,
    l: usize,
    rows: usize,
    cols: usize,
) -> Result<Mat> {
    let slice = base_layout.view_layer(base, name, l)?;
    anyhow::ensure!(slice.len() == rows * cols);
    Ok(Mat::from_vec(rows, cols, slice.to_vec()))
}

/// Transform one weight matrix with this layer's adapter parameters.
pub fn transform_matrix(
    spec: &MethodSpec,
    peft: &[f32],
    peft_layout: &Layout,
    name: &str,
    l: usize,
    w: &Mat,
) -> Result<Mat> {
    let n = spec.n_blocks;
    let (d, f) = (w.rows, w.cols);
    let get = |field: &str| peft_layout.view_layer(peft, &format!("{name}.{field}"), l);
    Ok(match spec.kind {
        MethodKind::None => w.clone(),
        MethodKind::Ether => tf::ether_apply(get("u")?, n, w),
        MethodKind::EtherPlus => {
            let mut out = tf::ether_plus_left(get("u")?, get("v")?, n, w);
            if spec.sides == 2 {
                out = tf::ether_plus_right(&out, get("ru")?, get("rv")?, n);
            }
            out
        }
        MethodKind::Oft => {
            let blocks = tf::cayley_blocks(get("r")?, n, d / n);
            let mut out = tf::bdmm(&blocks, w);
            if spec.magnitude_refit {
                let mag = get("mag")?;
                for r in 0..d {
                    let row = out.row_mut(r);
                    for c in 0..f {
                        row[c] *= 1.0 + mag[c];
                    }
                }
            }
            out
        }
        MethodKind::Naive => {
            let blocks = tf::naive_blocks(get("r")?, n, d / n);
            tf::bdmm(&blocks, w)
        }
        MethodKind::Lora => {
            let a = Mat::from_vec(d, spec.rank, get("a")?.to_vec());
            let b = Mat::from_vec(spec.rank, f, get("b")?.to_vec());
            tf::lora_apply(&a, &b, w)
        }
        MethodKind::Full => Mat::from_vec(d, f, get("w")?.to_vec()),
        MethodKind::Vera => {
            // VeRA's frozen projections are jax-seeded HLO constants; the
            // host cannot reproduce them bit-exactly — merge via artifact.
            bail!("host merge unsupported for vera (use the merge artifact)")
        }
    })
}

/// Merge an adapter into a copy of the base weights (all layers, all six
/// adapted matrices). Mirrors the HLO `merge` artifact.
pub fn merge_into_base(
    dims: ModelDims,
    spec: &MethodSpec,
    base: &[f32],
    base_layout: &Layout,
    peft: &[f32],
    peft_layout: &Layout,
) -> Result<Vec<f32>> {
    let mut out = base.to_vec();
    for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
        for l in 0..dims.n_layers {
            let w = weight_matrix(base, base_layout, name, l, d, f)?;
            let t = transform_matrix(spec, peft, peft_layout, name, l, &w)?;
            base_layout
                .view_layer_mut(&mut out, name, l)?
                .copy_from_slice(&t.data);
        }
    }
    Ok(out)
}

/// Build the peft layout the same way `python/compile/peft.py` does
/// (used when no manifest is available, e.g. pure-host studies).
pub fn peft_layout_for(dims: ModelDims, spec: &MethodSpec) -> Layout {
    let mut items: Vec<(String, Vec<usize>)> = vec![];
    let l = dims.n_layers;
    let n = spec.n_blocks;
    let r = spec.rank;
    for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
        match spec.kind {
            MethodKind::Ether => items.push((format!("{name}.u"), vec![l, n, d / n])),
            MethodKind::EtherPlus => {
                items.push((format!("{name}.u"), vec![l, n, d / n]));
                items.push((format!("{name}.v"), vec![l, n, d / n]));
                if spec.sides == 2 {
                    items.push((format!("{name}.ru"), vec![l, n, f / n]));
                    items.push((format!("{name}.rv"), vec![l, n, f / n]));
                }
            }
            MethodKind::Oft => {
                items.push((format!("{name}.r"), vec![l, n, d / n, d / n]));
                if spec.magnitude_refit {
                    items.push((format!("{name}.mag"), vec![l, f]));
                }
            }
            MethodKind::Naive => items.push((format!("{name}.r"), vec![l, n, d / n, d / n])),
            MethodKind::Lora => {
                items.push((format!("{name}.a"), vec![l, d, r]));
                items.push((format!("{name}.b"), vec![l, r, f]));
            }
            MethodKind::Vera => {
                items.push((format!("{name}.dv"), vec![l, r]));
                items.push((format!("{name}.bv"), vec![l, f]));
            }
            MethodKind::Full => items.push((format!("{name}.w"), vec![l, d, f])),
            MethodKind::None => {}
        }
    }
    Layout::new(items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_dims() -> ModelDims {
        ModelDims { d_model: 16, d_ff: 32, n_layers: 2 }
    }

    fn fake_base(dims: ModelDims) -> (Vec<f32>, Layout) {
        // Only the six adapted matrices — enough for merge tests.
        let l = dims.n_layers;
        let layout = Layout::new(
            adapted_matrices(dims.d_model, dims.d_ff)
                .into_iter()
                .map(|(n, d, f)| (n.to_string(), vec![l, d, f]))
                .collect(),
        );
        let mut rng = Rng::new(11);
        (rng.normal_vec(layout.total, 0.05), layout)
    }

    #[test]
    fn merge_neutral_methods_are_identity() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        for name in ["oft_n4", "naive_n4", "lora_r4"] {
            let spec = MethodSpec::parse(name).unwrap();
            let pl = peft_layout_for(dims, &spec);
            // zero init except lora.a (any value works since b = 0)
            let peft = vec![0.0; pl.total];
            let merged =
                merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
            let diff: f32 = merged
                .iter()
                .zip(&base)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(diff < 1e-5, "{name}: {diff}");
        }
        // etherplus neutral when v == u
        let spec = MethodSpec::parse("etherplus_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(5);
        let mut peft = vec![0.0; pl.total];
        for (mname, _, _) in adapted_matrices(dims.d_model, dims.d_ff) {
            for l in 0..dims.n_layers {
                let u: Vec<f32> = rng.normal_vec(
                    pl.entry(&format!("{mname}.u")).unwrap().size / dims.n_layers,
                    1.0,
                );
                pl.view_layer_mut(&mut peft, &format!("{mname}.u"), l)
                    .unwrap()
                    .copy_from_slice(&u);
                pl.view_layer_mut(&mut peft, &format!("{mname}.v"), l)
                    .unwrap()
                    .copy_from_slice(&u);
                let ru: Vec<f32> = rng.normal_vec(
                    pl.entry(&format!("{mname}.ru")).unwrap().size / dims.n_layers,
                    1.0,
                );
                pl.view_layer_mut(&mut peft, &format!("{mname}.ru"), l)
                    .unwrap()
                    .copy_from_slice(&ru);
                pl.view_layer_mut(&mut peft, &format!("{mname}.rv"), l)
                    .unwrap()
                    .copy_from_slice(&ru);
            }
        }
        let merged = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
        let diff: f32 = merged
            .iter()
            .zip(&base)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-5, "{diff}");
    }

    #[test]
    fn ether_merge_preserves_frobenius_per_matrix() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let spec = MethodSpec::parse("ether_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(6);
        let peft = rng.normal_vec(pl.total, 1.0);
        let merged = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
        for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
            for l in 0..dims.n_layers {
                let w0 = weight_matrix(&base, &bl, name, l, d, f).unwrap();
                let w1 = weight_matrix(&merged, &bl, name, l, d, f).unwrap();
                assert!((w0.fro() - w1.fro()).abs() < 1e-3, "{name}[{l}]");
                assert!(w0.max_abs_diff(&w1) > 1e-4, "{name}[{l}] unchanged");
            }
        }
    }

    #[test]
    fn vera_host_merge_rejected() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let spec = MethodSpec::parse("vera_r4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let peft = vec![0.0; pl.total];
        assert!(merge_into_base(dims, &spec, &base, &bl, &peft, &pl).is_err());
    }
}
