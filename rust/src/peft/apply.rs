//! Apply a PEFT adapter to full base weights on the host ("host merge").
//!
//! The serving coordinator uses this path (or the HLO `merge` artifact)
//! on its merge-cache-miss hot path; it also backs (a) the perturbation
//! and distance studies that sweep transform parameters without a
//! runtime, (b) parity tests against the artifact, and (c) the merge
//! micro-benchmarks.
//!
//! The engine is a [`MergePlan`]: all (matrix, layer) work items are
//! enumerated once against the base layout, parameter views are resolved
//! up front, and the sweep executes as one `parallel_for_chunks` pass in
//! which each worker writes its items' transformed weights **directly
//! into the output buffer** through the layout offsets — no per-matrix
//! `Mat` clones. Work items use the single-threaded slice kernels from
//! [`crate::peft::transforms`], which are bit-deterministic, so the
//! parallel sweep is bit-identical to [`MergePlan::execute_serial`]
//! (locked in by `rust/tests/merge_parallel.rs`).

use anyhow::{bail, Result};

use crate::peft::flat::Layout;
use crate::peft::transforms as tf;
use crate::peft::{adapted_matrices, MethodKind, MethodSpec};
use crate::tensor::Mat;
use crate::util::pool::{parallel_for_chunks, parallel_for_chunks_with, SendPtr};

/// Model dimensions needed to interpret the layer-stacked layouts.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
}

/// Extract layer `l` of adapted matrix `name` from the flat base weights.
pub fn weight_matrix(
    base: &[f32],
    base_layout: &Layout,
    name: &str,
    l: usize,
    rows: usize,
    cols: usize,
) -> Result<Mat> {
    let slice = base_layout.view_layer(base, name, l)?;
    anyhow::ensure!(slice.len() == rows * cols);
    Ok(Mat::from_vec(rows, cols, slice.to_vec()))
}

/// Transform one weight matrix with this layer's adapter parameters
/// (blocked parallel kernels; used by the analysis drivers that work on
/// individual matrices rather than whole models).
pub fn transform_matrix(
    spec: &MethodSpec,
    peft: &[f32],
    peft_layout: &Layout,
    name: &str,
    l: usize,
    w: &Mat,
) -> Result<Mat> {
    let n = spec.n_blocks;
    let (d, f) = (w.rows, w.cols);
    let get = |field: &str| peft_layout.view_layer(peft, &format!("{name}.{field}"), l);
    Ok(match spec.kind {
        MethodKind::None => w.clone(),
        MethodKind::Ether => tf::ether_apply(get("u")?, n, w),
        MethodKind::EtherPlus => {
            let mut out = tf::ether_plus_left(get("u")?, get("v")?, n, w);
            if spec.sides == 2 {
                out = tf::ether_plus_right(&out, get("ru")?, get("rv")?, n);
            }
            out
        }
        MethodKind::Oft => {
            let blocks = tf::cayley_blocks(get("r")?, n, d / n);
            let scale = if spec.magnitude_refit { Some(get("mag")?) } else { None };
            tf::bdmm_scaled(&blocks, w, scale)
        }
        MethodKind::Naive => {
            let blocks = tf::naive_blocks(get("r")?, n, d / n);
            tf::bdmm(&blocks, w)
        }
        MethodKind::Lora => {
            let a = Mat::from_vec(d, spec.rank, get("a")?.to_vec());
            let b = Mat::from_vec(spec.rank, f, get("b")?.to_vec());
            tf::lora_apply(&a, &b, w)
        }
        MethodKind::Full => Mat::from_vec(d, f, get("w")?.to_vec()),
        MethodKind::Vera => {
            // VeRA's frozen projections are jax-seeded HLO constants; the
            // host cannot reproduce them bit-exactly — merge via artifact.
            bail!("host merge unsupported for vera (use the merge artifact)")
        }
    })
}

/// One (matrix, layer) unit of merge work, resolved to its flat-vector
/// location in the base layout.
#[derive(Clone, Copy, Debug)]
pub struct MergeItem {
    pub name: &'static str,
    pub layer: usize,
    pub rows: usize,
    pub cols: usize,
    /// Offset of this layer's matrix in the flat base vector.
    pub offset: usize,
}

/// Per-item adapter parameter views, resolved before the parallel sweep
/// so workers never touch the layout (and therefore cannot fail).
enum ItemParams<'a> {
    Ether { u: &'a [f32] },
    EtherPlus { u: &'a [f32], v: &'a [f32], right: Option<(&'a [f32], &'a [f32])> },
    Oft { r: &'a [f32], mag: Option<&'a [f32]> },
    Naive { r: &'a [f32] },
    Lora { a: &'a [f32], b: &'a [f32] },
    Full { w: &'a [f32] },
}

/// Pre-enumerated merge schedule: every adapted matrix × layer as an
/// independent work item over disjoint output ranges, plus the gap
/// ranges (non-adapted tensors) that are copied through from the base.
pub struct MergePlan {
    pub dims: ModelDims,
    pub items: Vec<MergeItem>,
    /// Ranges of the base vector not covered by any item.
    gaps: Vec<(usize, usize)>,
    base_total: usize,
}

impl MergePlan {
    /// Enumerate all work items once, validating the base layout.
    pub fn new(dims: ModelDims, base_layout: &Layout) -> Result<MergePlan> {
        let mut items = Vec::with_capacity(6 * dims.n_layers);
        for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
            let e = base_layout.entry(name)?;
            anyhow::ensure!(
                e.size == dims.n_layers * d * f,
                "base layout entry {name} has size {} != {} layers × {d}×{f}",
                e.size,
                dims.n_layers
            );
            for l in 0..dims.n_layers {
                items.push(MergeItem {
                    name,
                    layer: l,
                    rows: d,
                    cols: f,
                    offset: e.offset + l * d * f,
                });
            }
        }
        // Complement of the item ranges: copied (not transformed) by the
        // sweep, so `execute` fully writes `out` and callers never need a
        // redundant whole-base pre-copy.
        let mut ranges: Vec<(usize, usize)> =
            items.iter().map(|it| (it.offset, it.offset + it.rows * it.cols)).collect();
        ranges.sort_unstable();
        let mut gaps = vec![];
        let mut pos = 0;
        for (a, b) in ranges {
            if a > pos {
                gaps.push((pos, a));
            }
            pos = pos.max(b);
        }
        if pos < base_layout.total {
            gaps.push((pos, base_layout.total));
        }
        Ok(MergePlan { dims, items, gaps, base_total: base_layout.total })
    }

    /// Execute the plan as one parallel sweep. `out` is fully written:
    /// adapted regions receive the transformed weights and every other
    /// range is copied through from `base`, so callers can hand in any
    /// correctly-sized buffer (e.g. a freshly zero-allocated one) —
    /// no whole-base pre-copy needed.
    pub fn execute(
        &self,
        spec: &MethodSpec,
        base: &[f32],
        peft: &[f32],
        peft_layout: &Layout,
        out: &mut [f32],
    ) -> Result<()> {
        self.run(spec, base, peft, peft_layout, out, None)
    }

    /// Serial driver over the same kernels and item order — the
    /// determinism oracle: [`MergePlan::execute`] must produce identical
    /// bits.
    pub fn execute_serial(
        &self,
        spec: &MethodSpec,
        base: &[f32],
        peft: &[f32],
        peft_layout: &Layout,
        out: &mut [f32],
    ) -> Result<()> {
        self.run(spec, base, peft, peft_layout, out, Some(1))
    }

    fn run(
        &self,
        spec: &MethodSpec,
        base: &[f32],
        peft: &[f32],
        peft_layout: &Layout,
        out: &mut [f32],
        threads: Option<usize>,
    ) -> Result<()> {
        anyhow::ensure!(
            base.len() == self.base_total,
            "base length {} != layout total {}",
            base.len(),
            self.base_total
        );
        anyhow::ensure!(out.len() == base.len(), "output buffer length mismatch");
        if spec.kind == MethodKind::Vera {
            bail!("host merge unsupported for vera (use the merge artifact)");
        }
        if spec.kind == MethodKind::None {
            out.copy_from_slice(base);
            return Ok(());
        }
        // Pass the non-adapted tensors through.
        for &(a, b) in &self.gaps {
            out[a..b].copy_from_slice(&base[a..b]);
        }
        // Resolve every parameter view on this thread; the sweep below is
        // then infallible.
        let params: Vec<ItemParams> = self
            .items
            .iter()
            .map(|it| resolve_params(spec, peft, peft_layout, it))
            .collect::<Result<_>>()?;
        let items = &self.items;
        let params = &params;
        let ptr = SendPtr::new(out.as_mut_ptr());
        let sweep = |a: usize, b: usize| {
            for idx in a..b {
                let it = &items[idx];
                let size = it.rows * it.cols;
                // SAFETY: layout entries are non-overlapping, so items
                // cover disjoint [offset, offset + size) output ranges.
                let region =
                    unsafe { std::slice::from_raw_parts_mut(ptr.get().add(it.offset), size) };
                let src = &base[it.offset..it.offset + size];
                run_item(spec, it, &params[idx], src, region);
            }
        };
        match threads {
            Some(t) => parallel_for_chunks_with(t, items.len(), 1, sweep),
            None => parallel_for_chunks(items.len(), 1, sweep),
        }
        Ok(())
    }
}

fn resolve_params<'a>(
    spec: &MethodSpec,
    peft: &'a [f32],
    peft_layout: &Layout,
    it: &MergeItem,
) -> Result<ItemParams<'a>> {
    // Block-divisibility validation (the Mat-based transforms enforce
    // this with asserts; the slice kernels only debug_assert, so a
    // release build must be guarded here or a non-dividing n would
    // silently leave trailing rows untransformed).
    if spec.kind.is_multiplicative() {
        anyhow::ensure!(
            spec.n_blocks > 0 && it.rows % spec.n_blocks == 0,
            "{}[{}]: n_blocks={} must divide rows {}",
            it.name,
            it.layer,
            spec.n_blocks,
            it.rows
        );
        if spec.kind == MethodKind::EtherPlus && spec.sides == 2 {
            anyhow::ensure!(
                it.cols % spec.n_blocks == 0,
                "{}[{}]: n_blocks={} must divide cols {}",
                it.name,
                it.layer,
                spec.n_blocks,
                it.cols
            );
        }
    }
    // Every resolved view's length is checked against the item here —
    // the slice kernels only debug_assert sizes, so this is what keeps a
    // release build from silently part-transforming (or a worker thread
    // from panicking) on a peft layout inconsistent with ModelDims.
    let get = |field: &str, want: usize| -> Result<&'a [f32]> {
        let v = peft_layout.view_layer(peft, &format!("{}.{field}", it.name), it.layer)?;
        anyhow::ensure!(
            v.len() == want,
            "{}[{}].{field}: length {} != expected {want}",
            it.name,
            it.layer,
            v.len()
        );
        Ok(v)
    };
    let (d, f, n) = (it.rows, it.cols, spec.n_blocks);
    Ok(match spec.kind {
        MethodKind::Ether => ItemParams::Ether { u: get("u", d)? },
        MethodKind::EtherPlus => ItemParams::EtherPlus {
            u: get("u", d)?,
            v: get("v", d)?,
            right: if spec.sides == 2 { Some((get("ru", f)?, get("rv", f)?)) } else { None },
        },
        MethodKind::Oft => ItemParams::Oft {
            r: get("r", n * (d / n) * (d / n))?,
            mag: if spec.magnitude_refit { Some(get("mag", f)?) } else { None },
        },
        MethodKind::Naive => ItemParams::Naive { r: get("r", n * (d / n) * (d / n))? },
        MethodKind::Lora => ItemParams::Lora {
            a: get("a", d * spec.rank)?,
            b: get("b", spec.rank * f)?,
        },
        MethodKind::Full => ItemParams::Full { w: get("w", d * f)? },
        MethodKind::None | MethodKind::Vera => unreachable!("filtered in MergePlan::run"),
    })
}

/// Transform one work item from `src` (its slice of the base) into
/// `out` (its slice of the merged buffer). Infallible by construction.
fn run_item(spec: &MethodSpec, it: &MergeItem, params: &ItemParams, src: &[f32], out: &mut [f32]) {
    let n = spec.n_blocks;
    let (d, f) = (it.rows, it.cols);
    match params {
        ItemParams::Ether { u } => {
            let uh = tf::normalize_blocks(u, n);
            tf::ether_into(&uh, n, src, f, out);
        }
        ItemParams::EtherPlus { u, v, right } => {
            let uh = tf::normalize_blocks(u, n);
            let vh = tf::normalize_blocks(v, n);
            tf::ether_plus_left_into(&uh, &vh, n, src, f, out);
            if let Some((ru, rv)) = right {
                let ruh = tf::normalize_blocks(ru, n);
                let rvh = tf::normalize_blocks(rv, n);
                tf::ether_plus_right_rows(out, f, &ruh, &rvh, n);
            }
        }
        ItemParams::Oft { r, mag } => {
            let blocks = tf::cayley_blocks(r, n, d / n);
            tf::bdmm_into(&blocks, src, f, *mag, out);
        }
        ItemParams::Naive { r } => {
            let blocks = tf::naive_blocks(r, n, d / n);
            tf::bdmm_into(&blocks, src, f, None, out);
        }
        ItemParams::Lora { a, b } => tf::lora_into(a, b, src, d, spec.rank, f, out),
        ItemParams::Full { w } => out.copy_from_slice(w),
    }
}

/// Merge an adapter into a copy of the base weights (all layers, all six
/// adapted matrices) — one blocked parallel sweep. Mirrors the HLO
/// `merge` artifact.
pub fn merge_into_base(
    dims: ModelDims,
    spec: &MethodSpec,
    base: &[f32],
    base_layout: &Layout,
    peft: &[f32],
    peft_layout: &Layout,
) -> Result<Vec<f32>> {
    let plan = MergePlan::new(dims, base_layout)?;
    // Zero-alloc (calloc) rather than cloning the base: the sweep writes
    // every byte (items + gaps), so a base pre-copy would be pure wasted
    // memory bandwidth on the cache-miss hot path.
    let mut out = vec![0.0f32; base.len()];
    plan.execute(spec, base, peft, peft_layout, &mut out)?;
    Ok(out)
}

/// The pre-refactor per-matrix scalar merge, kept as the parity oracle
/// for the blocked engine and as the benchmark baseline.
pub fn merge_into_base_reference(
    dims: ModelDims,
    spec: &MethodSpec,
    base: &[f32],
    base_layout: &Layout,
    peft: &[f32],
    peft_layout: &Layout,
) -> Result<Vec<f32>> {
    let mut out = base.to_vec();
    for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
        for l in 0..dims.n_layers {
            let w = weight_matrix(base, base_layout, name, l, d, f)?;
            let t = transform_matrix_serial(spec, peft, peft_layout, name, l, &w)?;
            base_layout
                .view_layer_mut(&mut out, name, l)?
                .copy_from_slice(&t.data);
        }
    }
    Ok(out)
}

/// Serial scalar transform of one matrix (reference path only).
fn transform_matrix_serial(
    spec: &MethodSpec,
    peft: &[f32],
    peft_layout: &Layout,
    name: &str,
    l: usize,
    w: &Mat,
) -> Result<Mat> {
    let n = spec.n_blocks;
    let (d, f) = (w.rows, w.cols);
    let get = |field: &str| peft_layout.view_layer(peft, &format!("{name}.{field}"), l);
    Ok(match spec.kind {
        MethodKind::None => w.clone(),
        MethodKind::Ether => tf::ether_apply_serial(get("u")?, n, w),
        MethodKind::EtherPlus => {
            let mut out = tf::ether_plus_left_serial(get("u")?, get("v")?, n, w);
            if spec.sides == 2 {
                out = tf::ether_plus_right_serial(&out, get("ru")?, get("rv")?, n);
            }
            out
        }
        MethodKind::Oft => {
            let blocks = tf::cayley_blocks(get("r")?, n, d / n);
            let mut out = tf::bdmm_serial(&blocks, w);
            if spec.magnitude_refit {
                let mag = get("mag")?;
                for r in 0..d {
                    let row = out.row_mut(r);
                    for c in 0..f {
                        row[c] *= 1.0 + mag[c];
                    }
                }
            }
            out
        }
        MethodKind::Naive => {
            let blocks = tf::naive_blocks(get("r")?, n, d / n);
            tf::bdmm_serial(&blocks, w)
        }
        MethodKind::Lora => {
            let a = Mat::from_vec(d, spec.rank, get("a")?.to_vec());
            let b = Mat::from_vec(spec.rank, f, get("b")?.to_vec());
            tf::lora_apply(&a, &b, w)
        }
        MethodKind::Full => Mat::from_vec(d, f, get("w")?.to_vec()),
        MethodKind::Vera => {
            bail!("host merge unsupported for vera (use the merge artifact)")
        }
    })
}

/// Base layout holding exactly the six adapted matrices, layer-stacked
/// (`[n_layers, d, f]` each) — the synthetic-base convention shared by
/// the host benches, the merge tests, and the PJRT-free serving mode.
/// The companion of [`peft_layout_for`]: together they encode the host
/// side of the L2↔L3 shape contract.
pub fn base_layout_for(dims: ModelDims) -> Layout {
    Layout::new(
        adapted_matrices(dims.d_model, dims.d_ff)
            .into_iter()
            .map(|(name, d, f)| (name.to_string(), vec![dims.n_layers, d, f]))
            .collect(),
    )
}

/// Build the peft layout the same way `python/compile/peft.py` does
/// (used when no manifest is available, e.g. pure-host studies).
pub fn peft_layout_for(dims: ModelDims, spec: &MethodSpec) -> Layout {
    let mut items: Vec<(String, Vec<usize>)> = vec![];
    let l = dims.n_layers;
    let n = spec.n_blocks;
    let r = spec.rank;
    for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
        match spec.kind {
            MethodKind::Ether => items.push((format!("{name}.u"), vec![l, n, d / n])),
            MethodKind::EtherPlus => {
                items.push((format!("{name}.u"), vec![l, n, d / n]));
                items.push((format!("{name}.v"), vec![l, n, d / n]));
                if spec.sides == 2 {
                    items.push((format!("{name}.ru"), vec![l, n, f / n]));
                    items.push((format!("{name}.rv"), vec![l, n, f / n]));
                }
            }
            MethodKind::Oft => {
                items.push((format!("{name}.r"), vec![l, n, d / n, d / n]));
                if spec.magnitude_refit {
                    items.push((format!("{name}.mag"), vec![l, f]));
                }
            }
            MethodKind::Naive => items.push((format!("{name}.r"), vec![l, n, d / n, d / n])),
            MethodKind::Lora => {
                items.push((format!("{name}.a"), vec![l, d, r]));
                items.push((format!("{name}.b"), vec![l, r, f]));
            }
            MethodKind::Vera => {
                items.push((format!("{name}.dv"), vec![l, r]));
                items.push((format!("{name}.bv"), vec![l, f]));
            }
            MethodKind::Full => items.push((format!("{name}.w"), vec![l, d, f])),
            MethodKind::None => {}
        }
    }
    Layout::new(items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_dims() -> ModelDims {
        ModelDims { d_model: 16, d_ff: 32, n_layers: 2 }
    }

    fn fake_base(dims: ModelDims) -> (Vec<f32>, Layout) {
        // Only the six adapted matrices — enough for merge tests.
        let layout = base_layout_for(dims);
        let mut rng = Rng::new(11);
        (rng.normal_vec(layout.total, 0.05), layout)
    }

    #[test]
    fn merge_plan_enumerates_disjoint_cover() {
        let dims = tiny_dims();
        let (_, bl) = fake_base(dims);
        let plan = MergePlan::new(dims, &bl).unwrap();
        assert_eq!(plan.items.len(), 6 * dims.n_layers);
        let mut ranges: Vec<(usize, usize)> = plan
            .items
            .iter()
            .map(|it| (it.offset, it.offset + it.rows * it.cols))
            .collect();
        ranges.sort();
        for pair in ranges.windows(2) {
            assert!(pair[0].1 <= pair[1].0, "overlapping items {pair:?}");
        }
        let covered: usize = ranges.iter().map(|(a, b)| b - a).sum();
        assert_eq!(covered, bl.total, "items must cover the whole base");
    }

    #[test]
    fn merge_neutral_methods_are_identity() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        for name in ["oft_n4", "naive_n4", "lora_r4"] {
            let spec = MethodSpec::parse(name).unwrap();
            let pl = peft_layout_for(dims, &spec);
            // zero init except lora.a (any value works since b = 0)
            let peft = vec![0.0; pl.total];
            let merged =
                merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
            let diff: f32 = merged
                .iter()
                .zip(&base)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(diff < 1e-5, "{name}: {diff}");
        }
        // etherplus neutral when v == u
        let spec = MethodSpec::parse("etherplus_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(5);
        let mut peft = vec![0.0; pl.total];
        for (mname, _, _) in adapted_matrices(dims.d_model, dims.d_ff) {
            for l in 0..dims.n_layers {
                let u: Vec<f32> = rng.normal_vec(
                    pl.entry(&format!("{mname}.u")).unwrap().size / dims.n_layers,
                    1.0,
                );
                pl.view_layer_mut(&mut peft, &format!("{mname}.u"), l)
                    .unwrap()
                    .copy_from_slice(&u);
                pl.view_layer_mut(&mut peft, &format!("{mname}.v"), l)
                    .unwrap()
                    .copy_from_slice(&u);
                let ru: Vec<f32> = rng.normal_vec(
                    pl.entry(&format!("{mname}.ru")).unwrap().size / dims.n_layers,
                    1.0,
                );
                pl.view_layer_mut(&mut peft, &format!("{mname}.ru"), l)
                    .unwrap()
                    .copy_from_slice(&ru);
                pl.view_layer_mut(&mut peft, &format!("{mname}.rv"), l)
                    .unwrap()
                    .copy_from_slice(&ru);
            }
        }
        let merged = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
        let diff: f32 = merged
            .iter()
            .zip(&base)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-5, "{diff}");
    }

    #[test]
    fn ether_merge_preserves_frobenius_per_matrix() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let spec = MethodSpec::parse("ether_n4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let mut rng = Rng::new(6);
        let peft = rng.normal_vec(pl.total, 1.0);
        let merged = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
        for (name, d, f) in adapted_matrices(dims.d_model, dims.d_ff) {
            for l in 0..dims.n_layers {
                let w0 = weight_matrix(&base, &bl, name, l, d, f).unwrap();
                let w1 = weight_matrix(&merged, &bl, name, l, d, f).unwrap();
                assert!((w0.fro() - w1.fro()).abs() < 1e-3, "{name}[{l}]");
                assert!(w0.max_abs_diff(&w1) > 1e-4, "{name}[{l}] unchanged");
            }
        }
    }

    #[test]
    fn vera_host_merge_rejected() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let spec = MethodSpec::parse("vera_r4").unwrap();
        let pl = peft_layout_for(dims, &spec);
        let peft = vec![0.0; pl.total];
        assert!(merge_into_base(dims, &spec, &base, &bl, &peft, &pl).is_err());
        assert!(merge_into_base_reference(dims, &spec, &base, &bl, &peft, &pl).is_err());
    }

    #[test]
    fn blocked_merge_matches_reference_oracle() {
        let dims = tiny_dims();
        let (base, bl) = fake_base(dims);
        let mut rng = Rng::new(12);
        for name in ["ether_n4", "etherplus_n4", "etherplus_n2_1s", "oft_n4_mrf", "naive_n2", "lora_r4"] {
            let spec = MethodSpec::parse(name).unwrap();
            let pl = peft_layout_for(dims, &spec);
            let peft = rng.normal_vec(pl.total, 0.3);
            let fast = merge_into_base(dims, &spec, &base, &bl, &peft, &pl).unwrap();
            let slow = merge_into_base_reference(dims, &spec, &base, &bl, &peft, &pl).unwrap();
            let diff: f32 = fast
                .iter()
                .zip(&slow)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(diff <= 1e-5, "{name}: blocked vs reference diff {diff}");
        }
    }
}
