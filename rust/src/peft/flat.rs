//! Flat-vector parameter layouts (the L2↔L3 ABI).
//!
//! Every artifact exchanges parameters as a single flat f32 vector; the
//! manifest records `[[name, shape], …]` in vector order. `Layout` gives
//! named, shaped views into such vectors on the Rust side.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::util::json::Value;

#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// An ordered list of named tensors packed into one flat vector.
#[derive(Clone, Debug, Default)]
pub struct Layout {
    pub entries: Vec<Entry>,
    index: BTreeMap<String, usize>,
    pub total: usize,
}

impl Layout {
    pub fn new(items: Vec<(String, Vec<usize>)>) -> Layout {
        let mut entries = vec![];
        let mut index = BTreeMap::new();
        let mut offset = 0;
        for (name, shape) in items {
            let size: usize = shape.iter().product();
            index.insert(name.clone(), entries.len());
            entries.push(Entry { name, shape, offset, size });
            offset += size;
        }
        Layout { entries, index, total: offset }
    }

    /// Parse the manifest JSON form `[["name", [dims…]], …]`.
    pub fn from_json(v: &Value) -> Result<Layout> {
        let mut items = vec![];
        for pair in v.as_arr()? {
            let pair = pair.as_arr()?;
            let name = pair[0].as_str()?.to_string();
            let shape = pair[1]
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            items.push((name, shape));
        }
        Ok(Layout::new(items))
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.index
            .get(name)
            .map(|&i| &self.entries[i])
            .ok_or_else(|| anyhow!("layout has no entry {name:?}"))
    }

    /// Borrow the named tensor from a flat vector.
    pub fn view<'a>(&self, vec: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let e = self.entry(name)?;
        Ok(&vec[e.offset..e.offset + e.size])
    }

    pub fn view_mut<'a>(&self, vec: &'a mut [f32], name: &str) -> Result<&'a mut [f32]> {
        let e = self.entry(name)?;
        Ok(&mut vec[e.offset..e.offset + e.size])
    }

    /// Borrow layer `l` of a layer-stacked tensor (leading dim = layers).
    pub fn view_layer<'a>(&self, vec: &'a [f32], name: &str, l: usize) -> Result<&'a [f32]> {
        let e = self.entry(name)?;
        let per = e.size / e.shape[0];
        anyhow::ensure!(l < e.shape[0], "layer {l} out of range for {name}");
        Ok(&vec[e.offset + l * per..e.offset + (l + 1) * per])
    }

    pub fn view_layer_mut<'a>(
        &self,
        vec: &'a mut [f32],
        name: &str,
        l: usize,
    ) -> Result<&'a mut [f32]> {
        let e = self.entry(name)?;
        let per = e.size / e.shape[0];
        anyhow::ensure!(l < e.shape[0], "layer {l} out of range for {name}");
        Ok(&mut vec[e.offset + l * per..e.offset + (l + 1) * per])
    }

    pub fn has(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn demo() -> Layout {
        Layout::new(vec![
            ("a".into(), vec![2, 3]),
            ("b".into(), vec![4]),
            ("wq.u".into(), vec![2, 4, 8]),
        ])
    }

    #[test]
    fn offsets_and_total() {
        let l = demo();
        assert_eq!(l.total, 6 + 4 + 64);
        assert_eq!(l.entry("b").unwrap().offset, 6);
        assert_eq!(l.entry("wq.u").unwrap().offset, 10);
    }

    #[test]
    fn views() {
        let l = demo();
        let vec: Vec<f32> = (0..l.total).map(|i| i as f32).collect();
        assert_eq!(l.view(&vec, "b").unwrap(), &[6.0, 7.0, 8.0, 9.0]);
        let layer1 = l.view_layer(&vec, "wq.u", 1).unwrap();
        assert_eq!(layer1.len(), 32);
        assert_eq!(layer1[0], 10.0 + 32.0);
        assert!(l.view(&vec, "nope").is_err());
        assert!(l.view_layer(&vec, "wq.u", 2).is_err());
    }

    #[test]
    fn from_json_matches_manual() {
        let v = json::parse(r#"[["a", [2, 3]], ["b", [4]]]"#).unwrap();
        let l = Layout::from_json(&v).unwrap();
        assert_eq!(l.total, 10);
        assert_eq!(l.entry("a").unwrap().shape, vec![2, 3]);
    }
}
